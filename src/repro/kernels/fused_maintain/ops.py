"""Tree-level drivers for the fused_maintain kernel family.

``make_fused_maintain_fn`` builds the fabric's hot-loop program: one jitted
function ``(params, ckpt_values) -> (replica_tree, scores, parity)`` that
reads each live leaf once and produces all three maintenance outputs. The
host-side group metadata (sorted block order, compact parity rows, member
matrices) is precomputed per parity striping and baked into the program —
rebuilt by the fabric whenever the placement engine re-stripes.

``tree_scatter_save`` is the checkpoint-side counterpart: a donation-based
in-place partial save that moves only the selected blocks' bytes into the
running checkpoint instead of rewriting every leaf through ``jnp.where``.

Backend contract matches the other kernel packages: compiled Pallas on
TPU, the jnp path elsewhere (interpret-mode Pallas is for validation
only).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition, leaf_block_view
from repro.fabric.parity import FrameLayout
from repro.kernels.fused_maintain.kernel import (fused_maintain_pallas,
                                                 scatter_save_pallas)

PyTree = Any


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Host-side group metadata (static per parity striping)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafGroupMeta:
    """Per-leaf routing tables for the fused sweep (numpy, host-resident)."""
    perm: np.ndarray        # (S,) block ids sorted by parity group
    outrow: np.ndarray      # (S,) compact parity row per sorted position
    first: np.ndarray       # (S,) 1 at the first sorted position of its row
    touched: np.ndarray     # (n_out,) global group ids, ascending
    members: np.ndarray     # (n_out, m_hat) local block ids, -1 padded
    col: int                # column of this leaf's payload in the frame
    width: int              # payload width (int32 words)


def leaf_group_metas(partition: BlockPartition, layout: FrameLayout,
                     group_of: np.ndarray) -> list[LeafGroupMeta]:
    """Build each leaf's routing tables from the codec's group assignment."""
    group_of = np.asarray(group_of, np.int32)
    metas = []
    for leaf, col, width in zip(partition.leaves, layout.cols, layout.widths):
        gids = group_of[leaf.offset:leaf.offset + leaf.n_blocks]
        assert (gids >= 0).all(), \
            f"leaf {leaf.name}: blocks outside any parity group"
        order = np.argsort(gids, kind="stable").astype(np.int32)
        touched, inverse = np.unique(gids, return_inverse=True)
        outrow = inverse.astype(np.int32)[order]
        first = np.ones_like(outrow)
        first[1:] = (outrow[1:] != outrow[:-1]).astype(np.int32)
        m_hat = int(np.bincount(outrow).max())
        members = np.full((touched.size, m_hat), -1, np.int32)
        fill = np.zeros((touched.size,), np.int64)
        for pos, row in zip(order, outrow):
            members[row, fill[row]] = pos
            fill[row] += 1
        metas.append(LeafGroupMeta(perm=order, outrow=outrow, first=first,
                                   touched=touched.astype(np.int32),
                                   members=members, col=int(col),
                                   width=int(width)))
    return metas


# ---------------------------------------------------------------------------
# Fused maintenance program
# ---------------------------------------------------------------------------

def _leaf_sweep_pallas(x, z, meta: LeafGroupMeta, block_rows: int,
                       interpret: bool):
    xv = leaf_block_view(x, block_rows)
    zv = leaf_block_view(z.astype(x.dtype), block_rows)
    return fused_maintain_pallas(xv, zv, jnp.asarray(meta.perm),
                                 jnp.asarray(meta.outrow),
                                 jnp.asarray(meta.first),
                                 n_out_rows=int(meta.touched.size),
                                 interpret=interpret)


def _leaf_sweep_jnp(x, z, meta: LeafGroupMeta, block_rows: int):
    """jnp fast path: same outputs, one compact gather+fold per leaf —
    never the (total_blocks, frame_width) packed buffer of the seed path."""
    xv = leaf_block_view(x.astype(jnp.float32), block_rows)
    zv = leaf_block_view(z.astype(jnp.float32), block_rows)
    scores = jnp.sum((xv - zv) ** 2, axis=1)
    bits = jax.lax.bitcast_convert_type(xv, jnp.int32)
    idx = jnp.asarray(meta.members)
    valid = idx >= 0
    gathered = bits[jnp.where(valid, idx, 0)]        # (n_out, m_hat, E)
    contrib = jax.lax.reduce(jnp.where(valid[..., None], gathered, 0),
                             jnp.int32(0), jax.lax.bitwise_xor, (1,))
    replica = jax.tree_util.tree_map(jnp.array, x)
    return replica, scores, contrib


def make_fused_maintain_fn(partition: BlockPartition, layout: FrameLayout,
                           group_of: np.ndarray, n_groups: int,
                           use_pallas: Optional[bool] = None,
                           interpret: Optional[bool] = None,
                           ) -> Callable[[PyTree, PyTree], tuple]:
    """Build the jitted single-sweep maintenance program.

    Returns ``fn(params, ckpt_values) -> (replica_tree, scores, parity)``
    where ``scores`` is the (total_blocks,) squared-L2 drift vs the
    running checkpoint (colocated leaves accumulate, like
    :func:`repro.core.blocks.block_scores`) and ``parity`` is the
    (n_groups, frame_elems) int32 XOR parity — bit-identical to
    :meth:`ParityCodec.encode`'s result under the same striping.
    """
    if use_pallas is None:
        use_pallas = _is_tpu()
    if interpret is None:
        interpret = not _is_tpu()
    metas = leaf_group_metas(partition, layout, group_of)
    br = partition.block_rows

    def _maintain(params: PyTree, ckpt_values: PyTree):
        flat = jax.tree_util.tree_leaves(params)
        zflat = jax.tree_util.tree_leaves(ckpt_values)
        scores = jnp.zeros((partition.total_blocks,), jnp.float32)
        parity = jnp.zeros((n_groups, layout.frame_elems), jnp.int32)
        replicas = []
        for x, z, leaf, meta in zip(flat, zflat, partition.leaves, metas):
            if use_pallas:
                rep_v, sc, contrib = _leaf_sweep_pallas(x, z, meta, br,
                                                        interpret)
                rows = max(leaf.rows, 1)
                rep = rep_v.reshape(-1, max(leaf.row_width, 1))[:rows]
                rep = rep.reshape(leaf.shape)
            else:
                rep, sc, contrib = _leaf_sweep_jnp(x, z, meta, br)
            replicas.append(rep)
            scores = jax.lax.dynamic_update_slice(
                scores, jax.lax.dynamic_slice(
                    scores, (leaf.offset,), (leaf.n_blocks,)) + sc,
                (leaf.offset,))
            rows = jnp.asarray(meta.touched)
            cols = slice(meta.col, meta.col + meta.width)
            parity = parity.at[rows, cols].set(parity[rows, cols] ^ contrib)
        replica_tree = jax.tree_util.tree_unflatten(partition.treedef,
                                                    replicas)
        return replica_tree, scores, parity

    return jax.jit(_maintain)


# ---------------------------------------------------------------------------
# In-place partial save
# ---------------------------------------------------------------------------

_SCATTER_CACHE: dict = {}


def _bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clipped to cap — bounds jit recompiles to
    O(log cap) distinct selection sizes per leaf signature."""
    return min(1 << max(0, math.ceil(math.log2(max(n, 1)))), cap)


def _scatter_leaf_fn(shape: tuple, dtype, k_hat: int, block_rows: int,
                     use_pallas: bool, interpret: bool):
    key = (shape, str(dtype), k_hat, block_rows, use_pallas, interpret)
    fn = _SCATTER_CACHE.get(key)
    if fn is not None:
        return fn
    rows_total = shape[0] if len(shape) >= 1 else 1
    width = int(np.prod(shape[1:])) if len(shape) >= 1 else 1

    def _scatter(dst, src, sel):
        d2 = dst.reshape(max(rows_total, 1), max(width, 1))
        s2 = src.astype(dst.dtype).reshape(max(rows_total, 1), max(width, 1))
        if use_pallas:
            out = scatter_save_pallas(d2, s2, sel, block_rows,
                                      interpret=interpret)
        else:
            # row-expanded gather/scatter: duplicates from the clip and the
            # bucket padding rewrite identical values (idempotent)
            row_idx = (sel[:, None] * block_rows
                       + jnp.arange(block_rows)[None, :]).reshape(-1)
            row_idx = jnp.minimum(row_idx, max(rows_total, 1) - 1)
            out = d2.at[row_idx].set(s2[row_idx])
        return out.reshape(shape)

    fn = jax.jit(_scatter, donate_argnums=(0,))
    _SCATTER_CACHE[key] = fn
    return fn


def tree_scatter_save(dst: PyTree, src: PyTree, global_idx: np.ndarray,
                      partition: BlockPartition,
                      use_pallas: Optional[bool] = None,
                      interpret: Optional[bool] = None,
                      ) -> tuple[PyTree, int]:
    """Overwrite the selected blocks of ``dst`` from ``src`` in place.

    ``global_idx`` — host-resident selected global block ids. Leaves with
    no selected block pass through untouched (zero traffic); each touched
    leaf moves only its selected blocks' rows. Returns
    ``(updated_tree, bytes_moved)``. ``dst`` leaves are donated — callers
    must not reuse the input buffers of touched leaves.
    """
    if use_pallas is None:
        use_pallas = _is_tpu()
    if interpret is None:
        interpret = not _is_tpu()
    idx = np.unique(np.asarray(global_idx, np.int64))
    dst_flat = jax.tree_util.tree_leaves(dst)
    src_flat = jax.tree_util.tree_leaves(src)
    br = partition.block_rows
    out = []
    moved = 0
    # colocated leaves share block-id ranges; each leaf still scatters its
    # own payload for the shared ids
    for d, s, leaf in zip(dst_flat, src_flat, partition.leaves):
        lo = np.searchsorted(idx, leaf.offset)
        hi = np.searchsorted(idx, leaf.offset + leaf.n_blocks)
        sel = (idx[lo:hi] - leaf.offset).astype(np.int32)
        if sel.size == 0:
            out.append(d)
            continue
        k_hat = _bucket(sel.size, leaf.n_blocks)
        padded = np.full((k_hat,), sel[0], np.int32)
        padded[:sel.size] = sel
        fn = _scatter_leaf_fn(tuple(leaf.shape), leaf.dtype, k_hat, br,
                              use_pallas, interpret)
        out.append(fn(d, s, jnp.asarray(padded)))
        rows_per = np.minimum((sel + 1) * br, max(leaf.rows, 1)) - sel * br
        moved += int(rows_per.clip(min=0).sum()) * leaf.row_width \
            * np.dtype(leaf.dtype).itemsize
    return jax.tree_util.tree_unflatten(partition.treedef, out), moved


# ---------------------------------------------------------------------------
# Analytic traffic model (bytes per maintain step / per partial save)
# ---------------------------------------------------------------------------

def _tree_nbytes(partition: BlockPartition) -> int:
    return sum(int(np.prod(l.shape) or 1) * np.dtype(l.dtype).itemsize
               for l in partition.leaves)


def maintain_traffic(partition: BlockPartition, layout: FrameLayout,
                     group_of: np.ndarray, n_groups: int,
                     group_width: int) -> dict[str, int]:
    """Analytic HBM bytes moved by one full maintenance step (replica
    refresh + parity encode + priority scoring), seed path vs fused path.

    The seed path reads the live tree once per pass (replica copy, frame
    pack, score) plus writes/reads two full-model staging buffers (the
    packed ``(total_blocks, frame_elems)`` frames and the
    ``(n_groups, g, E)`` gather); the fused path reads the live tree and
    the checkpoint once, writes the replica, and touches only the compact
    per-leaf parity contributions.
    """
    model = _tree_nbytes(partition)
    frames = partition.total_blocks * layout.frame_elems * 4
    gathered = n_groups * group_width * layout.frame_elems * 4
    parity = n_groups * layout.frame_elems * 4
    metas = leaf_group_metas(partition, layout, group_of)
    contrib = sum(m.touched.size * m.width * 4 for m in metas)
    seed = (
        model + model            # replica: read live + write replica
        + model + frames         # pack_frames: read live + write frames
        + frames + gathered      # gather: read frames + write grouped
        + gathered + parity      # encode: read grouped + write parity
        + model + model          # block_scores: read live + read ckpt
    )
    fused = (
        model + model            # one sweep: read live + read ckpt
        + model                  # write replica
        + contrib                # write compact parity contributions
        + 2 * contrib + parity   # combine: read contribs, rmw parity cols
    )
    return {"seed": int(seed), "fused": int(fused), "model": int(model),
            "parity": int(parity), "staging_seed": int(frames + gathered),
            "staging_fused": int(contrib)}
