"""Block partition, running checkpoint and selection strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import (block_scores, expand_block_mask,
                               leaf_block_view, masked_sq_norm,
                               partition_pytree, select_blocks, tree_sq_norm)
from repro.core.checkpoint import (full_save, init_running_checkpoint,
                                   save_step)
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, SelectionStrategy


@pytest.fixture
def params():
    return {"w": jnp.arange(200.0, dtype=jnp.float32).reshape(50, 4),
            "b": jnp.ones((5,), jnp.float32),
            "scalar": jnp.float32(3.0)}


def test_partition_covers_everything(params):
    part = partition_pytree(params, block_rows=16)
    # every leaf gets ceil(rows/block_rows) blocks
    per_leaf = {l.name: l.n_blocks for l in part.leaves}
    assert per_leaf["['w']"] == 4     # ceil(50/16)
    assert per_leaf["['b']"] == 1
    assert per_leaf["['scalar']"] == 1
    assert part.total_blocks == 6
    assert part.total_params == 206


def test_leaf_block_view_pads_with_zeros(params):
    v = leaf_block_view(params["w"], 16)
    assert v.shape == (4, 64)
    # last block has 50-48=2 rows of data then zeros
    assert float(jnp.sum(v[3, 8:])) == 0.0


def test_select_blocks_semantics(params):
    part = partition_pytree(params, block_rows=16)
    other = jax.tree_util.tree_map(lambda x: x * 0 - 1.0, params)
    mask = jnp.zeros((part.total_blocks,), bool).at[1].set(True)  # scalar? order
    out = select_blocks(params, other, mask, part)
    leaves_in = jax.tree_util.tree_leaves(params)
    leaves_out = jax.tree_util.tree_leaves(out)
    # exactly the rows of the masked block changed
    changed = sum(int(jnp.sum(a != b)) for a, b in zip(leaves_in, leaves_out))
    assert changed > 0


def test_masked_norm_matches_dense(params):
    part = partition_pytree(params, block_rows=16)
    other = jax.tree_util.tree_map(lambda x: x + 2.0, params)
    full_mask = jnp.ones((part.total_blocks,), bool)
    assert float(masked_sq_norm(params, other, full_mask, part)) == \
        pytest.approx(float(tree_sq_norm(params, other)), rel=1e-6)


def test_priority_selects_most_drifted(params):
    pol = CheckpointPolicy(fraction=0.2, full_interval=10,
                           strategy=SelectionStrategy.PRIORITY)
    part = partition_pytree(params, pol.block_rows)
    ckpt = init_running_checkpoint(params, part)
    # drift only rows 0..15 of w (block 0 of w)
    drifted = {**params, "w": params["w"].at[:16].add(100.0)}
    norm = get_norm("l2")
    new_ckpt, mask = save_step(ckpt, drifted, jnp.int32(3), policy=pol,
                               partition=part, norm_fn=norm)
    k = part.blocks_for_k(0.2)
    assert int(mask.sum()) == k
    # the w block 0 must be selected; find w leaf offset
    w_leaf = [l for l in part.leaves if l.name == "['w']"][0]
    assert bool(mask[w_leaf.offset])
    # checkpoint now holds the drifted values for that block
    assert float(new_ckpt.values["w"][0, 0]) == pytest.approx(100.0)
    assert int(new_ckpt.saved_iter[w_leaf.offset]) == 3


def test_round_robin_cycles(params):
    pol = CheckpointPolicy(fraction=0.34, full_interval=3,
                           strategy=SelectionStrategy.ROUND_ROBIN)
    part = partition_pytree(params, pol.block_rows)
    ckpt = init_running_checkpoint(params, part)
    seen = set()
    norm = get_norm("l2")
    for step in range(1, 5):
        ckpt, mask = save_step(ckpt, params, jnp.int32(step), policy=pol,
                               partition=part, norm_fn=norm)
        seen |= set(np.nonzero(np.asarray(mask))[0].tolist())
    assert seen == set(range(part.total_blocks))   # full coverage


def test_random_needs_rng(params):
    pol = CheckpointPolicy(fraction=0.5, full_interval=2,
                           strategy=SelectionStrategy.RANDOM)
    part = partition_pytree(params, pol.block_rows)
    ckpt = init_running_checkpoint(params, part)
    with pytest.raises(ValueError):
        save_step(ckpt, params, jnp.int32(1), policy=pol, partition=part,
                  norm_fn=get_norm("l2"))
    _, mask = save_step(ckpt, params, jnp.int32(1), policy=pol,
                        partition=part, norm_fn=get_norm("l2"),
                        rng=jax.random.PRNGKey(0))
    assert int(mask.sum()) == part.blocks_for_k(0.5)


def test_full_save(params):
    part = partition_pytree(params, 16)
    ckpt = init_running_checkpoint(params, part)
    p2 = jax.tree_util.tree_map(lambda x: x + 1, params)
    ckpt2 = full_save(ckpt, p2, jnp.int32(7))
    assert float(tree_sq_norm(ckpt2.values, p2)) == 0.0
    assert int(ckpt2.saved_iter[0]) == 7


def test_policy_validation():
    with pytest.raises(ValueError):
        CheckpointPolicy(fraction=0.0)
    with pytest.raises(ValueError):
        CheckpointPolicy(fraction=1.5)
    assert CheckpointPolicy.scar().partial_interval == 1
    assert CheckpointPolicy.traditional(8).full_interval == 8


def test_colocated_partition_shares_blocks():
    """PS reality: optimizer moments fail/recover WITH their parameters."""
    import numpy as np
    from repro.core.blocks import (block_scores, masked_sq_norm,
                                   select_blocks, tree_sq_norm)
    from repro.core.norms import get_norm
    tree = {"net": {"w": jnp.ones((16, 3)), "b": jnp.ones((4,))},
            "mu": {"w": jnp.zeros((16, 3)), "b": jnp.zeros((4,))},
            "nu": {"w": jnp.zeros((16, 3)), "b": jnp.zeros((4,))},
            "t": jnp.zeros((), jnp.int32)}
    part = partition_pytree(tree, 8, colocate=("net", "mu", "nu"))
    assert part.total_blocks == 4           # b-group, 2 w-group blocks, t
    other = jax.tree_util.tree_map(lambda x: x + 1, tree)
    mask = jnp.zeros((4,), bool).at[1].set(True)   # first w-group block
    out = select_blocks(tree, other, mask, part)
    # the same rows flip in net.w AND mu.w AND nu.w — never mixed state
    for g in ("net", "mu", "nu"):
        assert float(out[g]["w"][0, 0]) == float(other[g]["w"][0, 0])
        assert float(out[g]["w"][15, 0]) == float(tree[g]["w"][15, 0])
        assert float(out[g]["b"][0]) == float(tree[g]["b"][0])
    # scores accumulate per group; full-mask norm is exact
    full = jnp.ones((4,), bool)
    np.testing.assert_allclose(
        float(masked_sq_norm(tree, other, full, part)),
        float(tree_sq_norm(tree, other)), rtol=1e-6)
