"""Production mesh construction (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.

- single-pod: (16, 16)   axes ("data", "model")   — 256 chips
- multi-pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips,
  pure data parallelism across pods (gradient all-reduce crosses DCI).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older releases lack it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def mesh_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=`` kwargs for ``jax.make_mesh``, or ``{}`` on jax
    versions without ``jax.sharding.AxisType`` (everything is Auto there)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` that works across jax versions (Auto axis types)."""
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"))
