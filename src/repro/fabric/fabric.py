"""The checkpoint fabric facade: topology + replicas + parity + planner.

``CheckpointFabric`` is the single object the FTController (and the
training loops) talk to:

- ``maintain(step, params)``      — refresh replicas / re-encode parity on
                                    their configured intervals (idempotent
                                    per step).
- ``sample_domain_failure(...)``  — correlated whole-domain failure: the
                                    lost-block mask plus the failed devices.
- ``on_failure(...)``             — tier-plan the lost blocks, recover each
                                    from the cheapest surviving tier, and
                                    report per-tier perturbation norms.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.blocks import BlockPartition
from repro.fabric.domains import FailureDomainMap
from repro.fabric.parity import ParityCodec
from repro.fabric.replica import ReplicaSet
from repro.fabric.tiers import TieredRecovery
from repro.sharding.partition import block_device_homes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    n_devices: int = 8
    devices_per_host: int = 2
    hosts_per_rack: int = 2
    replicate: bool = True
    replicate_interval: int = 1    # steps between replica refreshes
    parity: bool = True
    parity_group: int = 4          # members per XOR parity group
    parity_interval: int = 1       # steps between parity re-encodes
    use_pallas: Optional[bool] = None   # None = auto: Pallas on TPU only

    def __post_init__(self):
        if self.replicate_interval < 1 or self.parity_interval < 1:
            raise ValueError("maintenance intervals must be >= 1")


class CheckpointFabric:
    def __init__(self, partition: BlockPartition,
                 cfg: Optional[FabricConfig] = None,
                 homes: Optional[np.ndarray] = None):
        self.cfg = cfg or FabricConfig()
        self.partition = partition
        self.domains = FailureDomainMap(self.cfg.n_devices,
                                        self.cfg.devices_per_host,
                                        self.cfg.hosts_per_rack)
        self.homes = (np.asarray(homes, np.int32) if homes is not None
                      else block_device_homes(partition, self.cfg.n_devices))
        self.replicas = (ReplicaSet(partition, self.homes, self.domains)
                         if self.cfg.replicate else None)
        self.parity = (ParityCodec(partition, self.homes, self.domains,
                                   group_size=self.cfg.parity_group,
                                   use_pallas=self.cfg.use_pallas)
                       if self.cfg.parity else None)
        self.planner = TieredRecovery(partition, self.domains, self.homes,
                                      replicas=self.replicas,
                                      parity=self.parity)
        self.last_maintained_step = -1
        self.stats = {"replica_refreshes": 0, "parity_encodes": 0,
                      "recoveries": 0}

    # -- maintenance ---------------------------------------------------------

    def maintain(self, step: int, params: PyTree, force: bool = False) -> None:
        """Refresh redundancy tiers from live params (idempotent per step)."""
        step = int(step)
        if step == self.last_maintained_step and not force:
            return
        if self.replicas is not None and (
                force or step % self.cfg.replicate_interval == 0):
            self.replicas.refresh(step, params)
            self.stats["replica_refreshes"] += 1
        if self.parity is not None and (
                force or step % self.cfg.parity_interval == 0):
            self.parity.encode(step, params)
            self.stats["parity_encodes"] += 1
        self.last_maintained_step = step

    def redundancy_nbytes(self) -> dict[str, int]:
        return {
            "replica": self.replicas.nbytes() if self.replicas else 0,
            "parity": self.parity.nbytes() if self.parity else 0,
        }

    # -- failure injection ---------------------------------------------------

    def sample_domain_failure(self, rng: np.random.Generator,
                              kind: str = "host",
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Correlated whole-domain loss → (lost block mask, failed devices)."""
        failed = self.domains.sample_domain_failure(rng, kind)
        lost = np.isin(self.homes, failed)
        return lost, failed

    # -- recovery ------------------------------------------------------------

    def on_failure(self, params: PyTree, ckpt_values: PyTree,
                   lost_mask, failed_devices=None,
                   step: Optional[int] = None,
                   disk_values: Optional[PyTree] = None,
                   disk_reader=None,
                   ) -> tuple[PyTree, dict]:
        """Tier-planned recovery. ``failed_devices=None`` models the paper's
        uniform block loss (no device actually died — every redundancy tier
        survives). ``step=None`` assumes the failure hit at the last
        maintained step, i.e. replicas/parity are fresh."""
        if failed_devices is None:
            failed_devices = np.empty((0,), np.int32)
        if step is None:
            step = self.last_maintained_step
        plan = self.planner.plan(lost_mask, failed_devices, step)
        recovered, stats = self.planner.recover(params, ckpt_values, plan,
                                                disk_values=disk_values,
                                                disk_reader=disk_reader)
        self.stats["recoveries"] += 1
        stats["failed_devices"] = int(np.asarray(failed_devices).size)
        return recovered, stats
