"""Fault-tolerance controller (paper §4.3, Figure 4).

Host-side orchestrator that owns the running checkpoint and drives:

1. *Checkpoint coordination* — every ``policy.partial_interval`` iterations,
   score blocks (priority), update the in-memory running checkpoint
   (jitted, device-resident), and mirror the saved blocks to persistent
   storage. Training resumes as soon as the in-memory cache is updated;
   the disk write is a background-able host callback (paper §4.3 step 4).
2. *Recovery coordination* — on a detected failure (a lost block mask),
   partially (or fully) restore from the running checkpoint. If the
   in-memory replica itself was lost (total failure), reload from the
   persistent store.
3. *Fabric coordination* (optional ``fabric=``) — maintain the tiered
   redundancy fabric (anti-affine peer replicas + XOR parity,
   :mod:`repro.fabric`) alongside the running checkpoint, and route
   ``on_failure`` through the tier planner so each lost block recovers
   from the cheapest surviving tier, with per-tier perturbation stats.
   Trace-driven soaks use ``on_domain_event``/``heal_domain`` — failed
   domains stay dead in the fabric's cluster view (elastic fabrics
   re-home/re-seed across the survivors) and every event's tier counts
   land in ``stats["events"]``.

The controller is deliberately thin: all numerics are pure functions from
:mod:`repro.core.checkpoint` / :mod:`repro.core.recovery`, so it composes
with any training loop (including the big-model SPMD trainer).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (BlockPartition, block_scores,
                               partition_pytree, tree_sq_norm)
from repro.core.checkpoint import (RunningCheckpoint, full_save,
                                   init_running_checkpoint, save_step,
                                   select_save_mask)
from repro.core.norms import get_norm
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.core.recovery import (apply_failure_and_recover,
                                 perturbation_norms, sample_failure_mask)
from repro.telemetry.recorder import NULL_RECORDER

PyTree = Any


class FTController:
    """Checkpoint + recovery coordinator for one training job."""

    def __init__(self, params: PyTree, policy: CheckpointPolicy, *,
                 norm_aux: Optional[dict] = None,
                 store: Optional[Any] = None,
                 score_fn: Optional[Callable] = None,
                 rng: Optional[jax.Array] = None,
                 colocate: tuple = (),
                 fabric: Optional[Any] = None,
                 inplace_save: bool = True,
                 recorder: Optional[Any] = None,
                 mesh: Optional[Any] = None):
        self.policy = policy
        # unified telemetry (repro.telemetry): the NULL_RECORDER default
        # keeps every emit point a no-op; a real Recorder receives this
        # controller's stats as a registered scope, structured save /
        # failure / recovery events, and the per-recovery ledger entries
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        # donation-based partial save: scatter only the selected blocks
        # into the running checkpoint (O(k·block_bytes)) instead of
        # rewriting every leaf through a full-size jnp.where
        self.inplace_save = inplace_save
        self.partition = partition_pytree(params, policy.block_rows,
                                          colocate=colocate)
        self.norm_fn = get_norm(policy.norm, aux=norm_aux,
                                block_rows=policy.block_rows)
        # flat-arena checkpoint state (set up after the fabric below):
        # when active, _ckpt_arena is the canonical running-checkpoint
        # value store and _ckpt.values may be stale (_ckpt_dirty) until
        # the ckpt property re-materializes the tree on demand
        self._arena_layout = None
        self._ckpt_arena = None
        self._ckpt_dirty = False
        self._pack_jit = None
        self._unpack_jit = None
        self._arena_score_jit = None
        self._arena_score_live_jit = None
        self._ckpt = init_running_checkpoint(params, self.partition)
        self.store = store
        self._score_fn = score_fn  # optional kernel-backed scorer
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        # np generator for topology sampling, derived from the jax key
        # (key_data handles both legacy uint32 and typed key arrays)
        np_seed = int(np.asarray(
            jax.random.key_data(self._rng)).ravel()[-1])
        self._np_rng = np.random.default_rng(np_seed)
        # fabric: a CheckpointFabric, or a FabricConfig to build one over
        # this controller's partition (import deferred so fabric-less
        # controllers never pay the fabric/kernel import chain)
        if fabric is not None:
            from repro.fabric import CheckpointFabric, FabricConfig
            if isinstance(fabric, FabricConfig):
                fabric = CheckpointFabric(self.partition, fabric,
                                          recorder=self.recorder,
                                          mesh=mesh)
            elif self.recorder.enabled:
                fabric.attach_recorder(self.recorder)
            if policy.recovery == RecoveryMode.FULL:
                # the tier planner is inherently partial (survivors keep
                # live values); a FULL-recovery baseline must not silently
                # degrade into it
                raise ValueError("fabric recovery is tiered/partial; use "
                                 "recovery=RecoveryMode.PARTIAL or drop "
                                 "the fabric for a FULL-recovery baseline")
        self.fabric = fabric
        self.stats = self.recorder.scope("controller", {
            "saves": 0, "recoveries": 0, "save_seconds": 0.0,
            "blocks_saved": 0, "bytes_mirrored": 0,
            "save_bytes_moved": 0, "events": []})
        self._jit_save = jax.jit(partial(
            save_step, policy=self.policy, partition=self.partition,
            norm_fn=self.norm_fn))
        self._jit_select = jax.jit(partial(
            select_save_mask, policy=self.policy, partition=self.partition,
            norm_fn=self.norm_fn))
        # arena checkpoint mode: the running checkpoint's values live as
        # the fabric's flat parameter arena — every partial save is ONE
        # donated tile scatter sourced from the maintenance sweep's
        # replica arena. Requires an arena-capable fabric, the in-place
        # save, and (for PRIORITY) squared-L2 scoring — custom scorers
        # and norms keep the tree-path save.
        if (inplace_save and self.fabric is not None
                and getattr(self.fabric, "arena_layout", None) is not None
                and score_fn is None
                and (policy.strategy != SelectionStrategy.PRIORITY
                     or policy.norm == "l2")):
            from repro.core.arena import pack_arena, unpack_arena
            layout = self.fabric.arena_layout
            sh = getattr(self.fabric, "_arena_sharding", None)
            self._arena_layout = layout
            self._pack_jit = jax.jit(
                lambda t: pack_arena(t, layout, out_sharding=sh))
            self._unpack_jit = jax.jit(lambda a: unpack_arena(a, layout))
            self._ckpt_arena = self._pack_jit(params)
        if store is not None:
            if self.recorder.enabled and hasattr(store, "attach_recorder"):
                store.attach_recorder(self.recorder)
            kw = {}
            if self.fabric is not None:
                # domain-keyed disk layout: DISK-tier reads after a domain
                # loss touch only the needed blocks' files
                kw = dict(homes=self.fabric.view.homes,
                          domains=self.fabric.domains)
            if self._arena_layout is not None:
                # arena-segment store layout: one append write per host
                # per save, sourced straight from the checkpoint arena
                kw["arena_layout"] = self._arena_layout
                kw["arena_values"] = np.asarray(self._ckpt_arena)
            store.init(params, self.partition, **kw)

    # -- arena-native live state --------------------------------------------

    @property
    def arena_layout(self):
        """The flat-arena layout of the hot path (None = tree-only)."""
        return self._arena_layout

    @property
    def arena_ready(self) -> bool:
        """True when the hot path is arena-native — the training loops
        may then feed :meth:`maintain`/:meth:`maybe_checkpoint` (and the
        recovery entry points) the live flat arena instead of the tree,
        eliminating the per-step ``pack_arena``."""
        return self._arena_layout is not None

    def pack_live(self, params: PyTree, account: bool = False) -> jnp.ndarray:
        """Pack a live tree into arena form (jitted; used once at
        training-state init and by tree-stepping runners that keep the
        controller interface arena-native).

        ``account=True`` books the pack's traffic (read the live tree,
        write the arena) onto the fabric's maintenance byte counter —
        tree-stepping runners pass it so their per-iteration pack is not
        silently dropped from the accounting when the downstream sweep
        runs at the pack-free resident rate. Truly resident callers
        (``ArenaTrainState`` init) leave it False: that pack happens once,
        not per step."""
        assert self.arena_ready, "controller has no arena layout"
        if account and self.fabric is not None:
            t = self.fabric._traffic_model()
            self.fabric.stats["maintain_bytes_moved"] += \
                t["model"] + t["arena_bytes"]
            self.fabric.stats["live_packs"] += 1
        return self._pack_jit(params)

    def unpack_live(self, arena: jnp.ndarray) -> PyTree:
        """Decode an arena back to tree form (recovery/analysis paths)."""
        assert self.arena_ready, "controller has no arena layout"
        return self._unpack_jit(arena)

    def rebind_arena(self) -> None:
        """Adopt the fabric's *current* arena layout after an elastic mesh
        resize (:meth:`CheckpointFabric.resize_mesh`): rebuilds the
        pack/unpack/score programs for the new shard count and relayouts
        the running-checkpoint arena onto the new mesh — the data region
        is layout-invariant, so the checkpoint values are bit-preserved
        through any number of shrink/re-grow cycles."""
        assert self.arena_ready and self.fabric is not None, \
            "rebind_arena needs an arena-native controller with a fabric"
        from repro.core.arena import pack_arena, relayout_arena, unpack_arena
        old = self._arena_layout
        layout = self.fabric.arena_layout
        sh = getattr(self.fabric, "_arena_sharding", None)
        self._arena_layout = layout
        self._pack_jit = jax.jit(
            lambda t: pack_arena(t, layout, out_sharding=sh))
        self._unpack_jit = jax.jit(lambda a: unpack_arena(a, layout))
        self._arena_score_jit = None
        self._arena_score_live_jit = None
        if self._ckpt_arena is not None and layout is not old:
            self._ckpt_arena = relayout_arena(self._ckpt_arena, old, layout,
                                              out_sharding=sh)
            self._ckpt_dirty = True

    def live_value_needed(self, step: int) -> bool:
        """True when this step's :meth:`maintain` or
        :meth:`maybe_checkpoint` will actually read the live value —
        tree-stepping runners skip their shared per-iteration pack (a
        full model+arena memcpy) on steps where nothing consumes it."""
        if self.should_checkpoint(int(step)):
            return True
        return (self.fabric is not None
                and any(self.fabric.maintenance_due(int(step))))

    def _live_arena(self, params):
        from repro.core.arena import as_live_arena
        return as_live_arena(params, self._arena_layout)

    # -- running checkpoint (arena-backed when the fabric has an arena) ------

    @property
    def ckpt(self) -> RunningCheckpoint:
        """The running checkpoint. In arena mode the canonical values are
        ``_ckpt_arena``; the tree form is re-materialized here on demand
        (recovery/analysis paths — never the per-save hot path)."""
        if self._ckpt_dirty:
            values = self._unpack_jit(self._ckpt_arena)
            self._ckpt = RunningCheckpoint(values, self._ckpt.saved_iter,
                                           self._ckpt.rr_cursor)
            self._ckpt_dirty = False
        return self._ckpt

    @ckpt.setter
    def ckpt(self, new: RunningCheckpoint) -> None:
        self._ckpt = new
        self._ckpt_dirty = False
        if self._arena_layout is not None:
            self._ckpt_arena = self._pack_jit(new.values)

    # -- checkpoint path ----------------------------------------------------

    def should_checkpoint(self, step: int) -> bool:
        interval = (self.policy.full_interval
                    if self.policy.fraction >= 1.0
                    else self.policy.partial_interval)
        return step > 0 and step % interval == 0

    def maybe_checkpoint(self, step: int, params: PyTree,
                         own_live: bool = False) -> bool:
        if not self.should_checkpoint(step):
            return False
        self.checkpoint_now(step, params, own_live=own_live)
        return True

    def checkpoint_now(self, step: int, params: PyTree,
                      own_live: bool = False) -> jnp.ndarray:
        """Update the running checkpoint; returns the saved block mask.

        ``params`` may be the live flat arena (arena-resident training
        state, requires :attr:`arena_ready`): the partial save then
        sources straight from the training state — no pack, no replica
        freshness gating — and a full save is one contiguous copy.
        ``own_live`` rides along to the post-save freshness maintain (see
        :meth:`maintain`) so a tree-stepping runner's throwaway pack is
        adopted, not re-copied, when that forced sweep runs."""
        if self.fabric is not None \
                and getattr(self.fabric, "has_pending_maintenance", False):
            # consume point: the save may source from the published slot
            # and mirrors parity afterwards — take the deferred fence
            # first, outside the save timer, so the in-flight sweep's
            # remainder books as fence time, not save time
            self.fabric.block_until_maintained()
        t0 = time.perf_counter()
        moved0 = self.stats["save_bytes_moved"]
        live = self._live_arena(params)
        full_plain = (self.policy.fraction >= 1.0 and
                      self.policy.strategy != SelectionStrategy.PRIORITY)
        arena_hot = self._arena_layout is not None and not full_plain
        if live is not None and full_plain:
            # full save from the live arena: ONE contiguous device copy
            ck = self._ckpt
            self._ckpt_arena = jnp.array(live)
            self._ckpt = RunningCheckpoint(
                ck.values, jnp.full_like(ck.saved_iter, jnp.int32(step)),
                ck.rr_cursor)
            self._ckpt_dirty = True
            mask = jnp.ones((self.partition.total_blocks,), bool)
        elif arena_hot:
            mask = self._arena_checkpoint(step, params)
        elif full_plain:
            self.ckpt = full_save(self.ckpt, params, jnp.int32(step))
            mask = jnp.ones((self.partition.total_blocks,), bool)
        else:
            assert live is None, ("live-arena saves need the arena "
                                  "checkpoint path (arena-capable fabric)")
            self._rng, sub = jax.random.split(self._rng)
            scores = None
            if self.policy.strategy == SelectionStrategy.PRIORITY:
                if self._score_fn is not None:
                    scores = self._score_fn(params, self.ckpt.values)
                elif (self.fabric is not None
                        and self.fabric.last_scores_step == int(step)
                        and self.policy.norm == "l2"):
                    # this step's fused maintenance sweep already measured
                    # the drift vs the running checkpoint — reuse it
                    # instead of a third full read of params + ckpt
                    scores = self.fabric.last_scores
            if self.inplace_save:
                mask, cursor = self._jit_select(self.ckpt, params, rng=sub,
                                                scores=scores)
                idx = np.nonzero(np.asarray(mask))[0]
                from repro.kernels.fused_maintain.ops import tree_scatter_save
                new_values, moved = tree_scatter_save(
                    self.ckpt.values, params, idx, self.partition)
                new_saved = jnp.where(mask, jnp.int32(step),
                                      self.ckpt.saved_iter)
                self.ckpt = RunningCheckpoint(new_values, new_saved, cursor)
                self.stats["save_bytes_moved"] += moved
            else:
                self.ckpt, mask = self._jit_save(self.ckpt, params,
                                                 jnp.int32(step), rng=sub,
                                                 scores=scores)
        if self.fabric is not None:
            # the save invalidated the drift the cached scores measured
            self.fabric.invalidate_scores()
        # block until the in-memory cache is consistent (paper: training may
        # resume now), then mirror to disk. In arena mode the arena IS the
        # cache — the tree form stays lazily dirty (never materialized on
        # the hot path).
        jax.block_until_ready(self._ckpt_arena if self._arena_layout
                              is not None else self.ckpt.values)
        n_blocks = int(jnp.sum(mask))
        save_seconds = time.perf_counter() - t0
        self.stats["saves"] += 1
        self.stats["blocks_saved"] += n_blocks
        self.stats["save_seconds"] += save_seconds
        if self.recorder.enabled:
            self.recorder.histogram("controller/save_seconds").observe(
                save_seconds)
            self.recorder.event(
                "save", step=int(step), blocks=n_blocks,
                bytes_moved=self.stats["save_bytes_moved"] - moved0,
                seconds=save_seconds,
                mode="arena" if self._arena_layout is not None else "tree")
        if self.store is not None:
            if self._arena_layout is not None:
                mask_np = np.asarray(mask)
                tiles = self._arena_layout.tiles_for_blocks(
                    np.nonzero(mask_np)[0])
                from repro.core.arena import ARENA_TILE
                data = np.asarray(
                    self._ckpt_arena.reshape(-1, ARENA_TILE)[tiles])
                self.stats["bytes_mirrored"] += self.store.write_arena(
                    mask_np, tiles, data, step,
                    background=self.policy.async_persist)
            else:
                self.stats["bytes_mirrored"] += self.store.write_blocks(
                    mask, self.ckpt.values, step,
                    background=self.policy.async_persist)
        if self.fabric is not None:
            if not self.fabric.is_fresh(int(step)):
                # keep the redundancy tiers at least as fresh as the
                # checkpoint (a same-step maintain() may have skipped an
                # off-interval tier — force refreshes every tier)
                self.fabric.maintain(int(step), params, force=True,
                                     own_live=own_live)
            if (self.store is not None
                    and getattr(self.fabric, "parity", None) is not None
                    and self.fabric.parity.parity is not None
                    and hasattr(self.store, "write_parity")):
                # mirror parity to disk: blocks whose domain shard died stay
                # reconstructable offline from survivors + parity
                self.stats["bytes_mirrored"] += self.store.write_parity(
                    int(step), np.asarray(self.fabric.parity.parity),
                    self.fabric.parity.parity_homes,
                    domains=self.fabric.domains,
                    members=self.fabric.parity.members)
        return mask

    def _arena_checkpoint(self, step: int, params: PyTree) -> jnp.ndarray:
        """Partial save in arena mode: select blocks, then ONE donated
        tile scatter into the checkpoint arena, sourced from the live
        arena itself when the training state is arena-resident (it *is*
        this step's values — no pack and no replica freshness gating),
        else from the maintenance sweep's replica arena (this step's
        snapshot — zero extra reads of the live tree) or, off-schedule,
        a fresh pack. O(k·seg_bytes) moved, a single dispatch each way."""
        from repro.kernels.fused_maintain.ops import arena_scatter_save
        pol = self.policy
        total = self.partition.total_blocks
        k = self.partition.blocks_for_k(pol.fraction)
        ck = self._ckpt
        cursor = ck.rr_cursor
        live = self._live_arena(params)
        self._rng, sub = jax.random.split(self._rng)
        if pol.strategy == SelectionStrategy.PRIORITY:
            if (self.fabric.last_scores_step == int(step)
                    and self.fabric.last_scores is not None):
                scores = self.fabric.last_scores
            else:
                scores = self._arena_scores(params)
            _, idx = jax.lax.top_k(scores, k)
            idx = np.asarray(idx)
        elif pol.strategy == SelectionStrategy.ROUND_ROBIN:
            c = int(ck.rr_cursor)
            idx = (c + np.arange(k)) % total
            cursor = jnp.int32((c + k) % total)
        elif pol.strategy == SelectionStrategy.RANDOM:
            idx = np.asarray(jax.random.choice(sub, total, (k,),
                                               replace=False))
        else:
            raise ValueError(f"unknown strategy {pol.strategy}")
        mask = np.zeros((total,), bool)
        mask[idx] = True
        rep = self.fabric.replicas
        published = (rep is not None and rep.arena is not None
                     and rep.is_fresh(int(step)))
        if self.fabric.cfg.async_maintain and published:
            # async mode: save off the published slot even when the live
            # arena is at hand — the snapshot holds this step's values
            # bit-exactly, and sourcing from it keeps the save's reads
            # off the buffer the next train step is about to donate
            # (arena_local: on a mesh the replica lives on the rotated
            # anti-affine device order and must be re-placed before it
            # can enter a jit with the flat-sharded checkpoint arena)
            src = rep.arena_local()
        elif live is not None:
            src = live
        elif published:
            src = rep.arena_local()
        else:
            src = self._pack_jit(params)
        self._ckpt_arena, moved = arena_scatter_save(
            self._ckpt_arena, src, self._arena_layout, idx,
            use_pallas=self.fabric.cfg.use_pallas)
        new_saved = jnp.where(jnp.asarray(mask), jnp.int32(step),
                              ck.saved_iter)
        self._ckpt = RunningCheckpoint(ck.values, new_saved, cursor)
        self._ckpt_dirty = True
        self.stats["save_bytes_moved"] += moved
        return jnp.asarray(mask)

    def _arena_scores(self, params: PyTree) -> jnp.ndarray:
        """Squared-L2 drift per block, computed arena-native (tile diff +
        segment-sum; a pack first when the live state arrives as a tree)
        — the PRIORITY fallback when this step's maintenance sweep didn't
        already cache the scores."""
        if self._arena_score_jit is None:
            from repro.core.arena import arena_drift_scores, pack_arena
            layout = self._arena_layout

            def _tile_scores(rep, z):
                # dtype-aware word scorer: decodes each word by its
                # stored dtype and handles word-packed tail blocks —
                # bit-identical to the historical f32 tile diff +
                # segment-sum on an all-f32 tail-free layout
                return arena_drift_scores(rep, z, layout)

            self._arena_score_jit = jax.jit(
                lambda p, z: _tile_scores(pack_arena(p, layout), z))
            self._arena_score_live_jit = jax.jit(_tile_scores)
        live = self._live_arena(params)
        if live is not None:
            return self._arena_score_live_jit(live, self._ckpt_arena)
        return self._arena_score_jit(params, self._ckpt_arena)

    def maintain(self, step: int, params: PyTree,
                 own_live: bool = False) -> None:
        """Per-iteration fabric upkeep (replica refresh / parity re-encode
        on their configured intervals). No-op without a fabric.

        When the policy's PRIORITY selection can consume fused scores
        (squared-L2 drift, no custom scorer), the running-checkpoint
        values ride along so the fused sweep scores blocks in the same
        read — the loops call maintain() *before* maybe_checkpoint() so a
        same-step save reuses them.

        ``params`` may be the live flat arena (arena-resident training
        state): the sweep then runs pack-free against it directly.
        ``own_live=True`` additionally hands the buffer over as the
        replica itself (no copy) — only for throwaway packs the caller
        will never donate or mutate (see
        :meth:`CheckpointFabric.maintain`)."""
        if self.fabric is None:
            return
        want_scores = (self.policy.strategy == SelectionStrategy.PRIORITY
                       and self.policy.norm == "l2"
                       and self._score_fn is None
                       and self.should_checkpoint(int(step)))
        if not want_scores:
            ckpt_values = None
        elif self._arena_layout is not None:
            # arena mode: the checkpoint arena feeds the sweep directly —
            # no tree materialization on the hot path
            ckpt_values = self._ckpt_arena
        else:
            ckpt_values = self.ckpt.values
        self.fabric.maintain(int(step), params, ckpt_values=ckpt_values,
                             own_live=own_live)

    # -- recovery path ------------------------------------------------------

    def sample_failure(self, fraction: float) -> jnp.ndarray:
        self._rng, sub = jax.random.split(self._rng)
        return sample_failure_mask(sub, self.partition, fraction)

    def sample_domain_failure(self, kind: str = "host",
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Correlated whole-domain failure → (lost mask, failed devices).
        Requires a fabric (it owns the failure-domain topology)."""
        assert self.fabric is not None, "domain failures need a fabric"
        return self.fabric.sample_domain_failure(self._np_rng, kind)

    def on_domain_event(self, params: PyTree, kind: str, index: int,
                        step: Optional[int] = None) -> tuple[PyTree, dict]:
        """Apply one trace event: fail a *specific* domain, recover, and —
        under the fabric's elastic mode — re-home/re-seed/re-stripe. The
        cluster view keeps the domain dead afterwards (trace semantics: the
        view tracks real cluster state) until :meth:`heal_domain`.
        Events on fully-dead domains are skipped."""
        assert self.fabric is not None, "domain events need a fabric"
        lost, failed = self.fabric.domain_failure(kind, index)
        if failed.size == 0:
            return params, {"skipped": True, "kind": kind, "index": index}
        recovered, info = self.on_failure(params, lost,
                                          failed_devices=failed, step=step,
                                          persist_failure=True)
        info["kind"], info["index"] = kind, index
        return recovered, info

    def on_domain_events(self, params: PyTree, events,
                         step: Optional[int] = None) -> tuple[PyTree, dict]:
        """Apply several trace events landing in the SAME step (correlated
        multi-domain loss — the multi-erasure case the RS tier exists
        for). Every event's loss is resolved against the pre-failure view
        *before* any device is marked dead, then the union recovers in ONE
        tier-planned pass: a block that lost both its primary and its
        replica domain sees the combined failure, exactly what a
        simultaneous loss means. A single event routes through
        :meth:`on_domain_event`, bit-identical to the one-event path."""
        assert self.fabric is not None, "domain events need a fabric"
        events = [(str(k), int(i)) for k, i in events]
        if len(events) == 1:
            return self.on_domain_event(params, *events[0], step=step)
        lost = np.zeros((self.partition.total_blocks,), bool)
        failed_parts, applied = [], []
        for kind, index in events:
            ev_lost, ev_failed = self.fabric.domain_failure(kind, index)
            if ev_failed.size == 0:
                continue
            lost |= ev_lost
            failed_parts.append(ev_failed)
            applied.append({"kind": kind, "index": index,
                            "failed_devices": int(ev_failed.size)})
        if not failed_parts:
            return params, {"skipped": True, "events": applied}
        failed = np.unique(np.concatenate(failed_parts))
        recovered, info = self.on_failure(params, lost,
                                          failed_devices=failed, step=step,
                                          persist_failure=True)
        info["events"] = applied
        return recovered, info

    def scrub(self, step: Optional[int] = None) -> dict:
        """Run the fabric's silent-error integrity pass and price it in
        the ledger: detected-and-corrected corruption applies ‖δ′‖² ≈ 0
        (the scrub restored the exact bits), so its ledger entry records
        the detection honestly at zero perturbation — the *undetected*
        window between scrubs is what a soak prices by comparing scrub
        cadence against the flip schedule. No-op (``checked=False``)
        without an integrity-capable fabric."""
        if self.fabric is None or not getattr(
                self.fabric.parity, "supports_integrity", False):
            return {"checked": False, "detected": 0, "corrected": 0,
                    "reports": []}
        out = self.fabric.scrub(step=step)
        if self.recorder.enabled and out["detected"]:
            self.recorder.record_recovery(
                step=None if step is None else int(step),
                lost_blocks=0,
                tier_counts={"SILENT_ERROR": out["detected"]},
                applied_sq=0.0,
                silent_detected=out["detected"],
                silent_corrected=out["corrected"])
        return out

    def heal_domain(self, kind: str, index: int,
                    params: Optional[PyTree] = None,
                    step: Optional[int] = None) -> dict:
        """Re-admit a healed domain to the fabric's cluster view (elastic
        fabrics also rebalance placement onto the restored capacity)."""
        assert self.fabric is not None, "domain healing needs a fabric"
        return self.fabric.heal_domain(kind, index, params=params, step=step)

    def on_failure(self, params: PyTree, lost_mask: jnp.ndarray,
                   failed_devices=None, step: Optional[int] = None,
                   persist_failure: Optional[bool] = None,
                   ) -> tuple[PyTree, dict]:
        """Recover from a partial failure. Returns (params', diagnostics).

        With a fabric, recovery routes through the tier planner: each lost
        block resolves to the cheapest surviving redundancy tier, and the
        diagnostics gain per-tier block counts and perturbation norms.
        ``failed_devices`` names the dead devices of a correlated failure
        (None = the paper's uniform block-loss model). ``persist_failure``
        (see :meth:`CheckpointFabric.on_failure`) keeps the devices dead in
        the cluster view — the trace-driven path sets it; one-shot
        experiments default to the fabric's ``elastic`` flag.

        ``params`` may be the live flat arena (arena-resident training
        state): recovery then decodes it once, runs the tier-planned tree
        recovery, and returns the recovered state re-packed as an arena —
        ONE contiguous write the caller drops straight back into its
        ``ArenaTrainState`` (the cold path pays the two conversions; the
        hot path never does).
        """
        live = self._live_arena(params)
        if live is not None:
            recovered, info = self.on_failure(
                self.unpack_live(live), lost_mask,
                failed_devices=failed_devices, step=step,
                persist_failure=persist_failure)
            return self.pack_live(recovered), info
        if self.recorder.enabled:
            self.recorder.event(
                "failure", step=None if step is None else int(step),
                lost_blocks=int(np.asarray(lost_mask, bool).sum()),
                failed_devices=(0 if failed_devices is None
                                else int(np.asarray(failed_devices).size)))
        ckpt = self.ckpt
        if self.store is not None and getattr(self.store, "must_reload", False):
            values = self.store.read_all()
            ckpt = RunningCheckpoint(values, ckpt.saved_iter, ckpt.rr_cursor)
        if self.fabric is not None:
            lost = np.asarray(lost_mask, bool)
            info = perturbation_norms(params, ckpt, jnp.asarray(lost),
                                      self.partition)
            disk_reader = None
            if self.store is not None:
                disk_reader = getattr(self.store, "read_blocks",
                                      self.store.read_all)
            recovered, tier_info = self.fabric.on_failure(
                params, ckpt.values, lost,
                failed_devices=failed_devices, step=step,
                disk_reader=disk_reader, persist_failure=persist_failure)
            info["applied_sq"] = tree_sq_norm(recovered, params)
            info["lost_blocks"] = int(lost.sum())
            info.update(tier_info)
            # per-event accounting: the trace-driven soak loops read this
            # off the controller to chart tier usage over a failure schedule
            self.stats["events"].append({
                "step": None if step is None else int(step),
                "lost_blocks": info["lost_blocks"],
                "failed_devices": info.get("failed_devices", 0),
                "tier_counts": info.get("tier_counts"),
                "applied_sq": float(info["applied_sq"]),
                "placement": info.get("placement"),
            })
        else:
            recovered, info = apply_failure_and_recover(
                params, ckpt, lost_mask, self.policy.recovery, self.partition)
        self.stats["recoveries"] += 1
        out = {k: (float(v) if hasattr(v, "item") else v)
               for k, v in info.items()}
        if self.recorder.enabled:
            # ledger entry + structured recovery event: the measured
            # ||δ'||² prices this failure in Thm-3.2/4.1 iterations.
            # Async recoveries also carry which epoch was actually
            # restored — a stale published slot is priced explicitly.
            extra = {}
            if "recovered_epoch" in out:
                extra["recovered_epoch"] = int(out["recovered_epoch"])
                extra["staleness"] = int(out.get("staleness", 0))
            self.recorder.record_recovery(
                step=None if step is None else int(step),
                lost_blocks=int(out.get("lost_blocks", 0)),
                tier_counts=out.get("tier_counts"),
                applied_sq=float(out.get("applied_sq", 0.0)),
                tier_sq=out.get("tier_sq"),
                failed_devices=out.get("failed_devices", 0),
                **extra)
        return recovered, out

    # -- analysis helpers ---------------------------------------------------

    def block_drift(self, params: PyTree) -> jnp.ndarray:
        """Per-block distance between live params and the running ckpt."""
        return block_scores(params, self.ckpt.values, self.partition,
                            self.norm_fn)
