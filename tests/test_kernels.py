"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c).

Kernels run in interpret=True mode on CPU (the kernel body executes in
Python) — the TPU is the compile target, interpret validates semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_dist.kernel import block_dist_pallas
from repro.kernels.block_dist.ref import block_dist_ref
from repro.kernels.masked_restore.kernel import masked_restore_pallas
from repro.kernels.masked_restore.ref import masked_restore_ref
from repro.kernels.ssd_scan.kernel import ssd_intra_pallas
from repro.kernels.ssd_scan.ref import ssd_intra_ref
from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
from repro.kernels.sw_attention.kernel import sw_attention_pallas
from repro.kernels.sw_attention.ref import sw_attention_ref

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# block_dist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1), (5, 100), (8, 512), (33, 777),
                                   (128, 2048), (7, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_dist_sweep(shape, dtype):
    a = jnp.asarray(RNG.normal(size=shape), dtype)
    b = jnp.asarray(RNG.normal(size=shape), dtype)
    got = block_dist_pallas(a, b, interpret=True)
    want = block_dist_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_block_dist_zero_distance():
    a = jnp.asarray(RNG.normal(size=(16, 300)), jnp.float32)
    np.testing.assert_allclose(block_dist_pallas(a, a, interpret=True),
                               np.zeros(16), atol=1e-7)


# ---------------------------------------------------------------------------
# masked_restore
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(3, 64), (8, 512), (21, 1000), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_restore_sweep(shape, dtype):
    dst = jnp.asarray(RNG.normal(size=shape), dtype)
    src = jnp.asarray(RNG.normal(size=shape), dtype)
    mask = jnp.asarray(RNG.random(shape[0]) < 0.5)
    got = masked_restore_pallas(dst, src, mask, interpret=True)
    want = masked_restore_ref(dst, src, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_masked_restore_all_none():
    dst = jnp.asarray(RNG.normal(size=(9, 70)), jnp.float32)
    src = jnp.asarray(RNG.normal(size=(9, 70)), jnp.float32)
    all_m = jnp.ones((9,), bool)
    none_m = jnp.zeros((9,), bool)
    np.testing.assert_array_equal(
        np.asarray(masked_restore_pallas(dst, src, all_m, interpret=True)),
        np.asarray(src))
    np.testing.assert_array_equal(
        np.asarray(masked_restore_pallas(dst, src, none_m, interpret=True)),
        np.asarray(dst))


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(2, 2, 16, 3, 8, 16), (1, 4, 32, 4, 16, 32),
                                  (2, 1, 8, 1, 4, 8), (1, 2, 64, 2, 32, 64)])
def test_ssd_intra_sweep(dims):
    B, nc, Q, H, P, N = dims
    la = -jnp.asarray(np.abs(RNG.normal(size=(B, nc, Q, H))), jnp.float32) * 0.1
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, nc, Q, H))), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(B, nc, Q, H, P)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, nc, Q, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, nc, Q, N)), jnp.float32)
    y1, s1 = ssd_intra_pallas(la, dt, x, Bm, Cm, interpret=True)
    y2, s2 = ssd_intra_ref(la, dt, x, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_ssd_full_matches_naive_recurrence():
    B, S, H, P, N, Q = 2, 48, 3, 8, 16, 16
    k = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(k[2], (H,)))
    Bm = jax.random.normal(k[3], (B, S, N))
    Cm = jax.random.normal(k[4], (B, S, N))
    y, hf = ssd_chunked_kernel(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A)
        h = h * a[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    np.testing.assert_allclose(y, jnp.stack(ys, 1), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(hf, h, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# sw_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [(2, 1, 64, 16, 16, 16, 16),
                                  (1, 2, 128, 32, 32, 32, 32),
                                  (2, 4, 96, 16, 24, 32, 16),
                                  (1, 1, 32, 8, 64, 16, 16)])
def test_sw_attention_sweep(dims):
    BH, G, S, Dh, W, qc, kc = dims
    q = jnp.asarray(RNG.normal(size=(BH, G, S, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BH, S, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BH, S, Dh)), jnp.float32)
    got = sw_attention_pallas(q, k, v, window=W, q_chunk=qc, kv_chunk=kc,
                              interpret=True)
    want = sw_attention_ref(q, k, v, window=W)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sw_attention_bf16():
    BH, G, S, Dh, W = 1, 2, 64, 16, 16
    q = jnp.asarray(RNG.normal(size=(BH, G, S, Dh)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(BH, S, Dh)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(BH, S, Dh)), jnp.bfloat16)
    got = sw_attention_pallas(q, k, v, window=W, q_chunk=16, kv_chunk=16,
                              interpret=True)
    want = sw_attention_ref(q, k, v, window=W)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
