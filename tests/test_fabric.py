"""Tiered checkpoint fabric: domains, anti-affinity, parity codec, planner.

Covers the subsystem invariants:
- replica placement is anti-affine to the primary home (host/rack level),
- the Pallas parity_xor kernel matches its jnp oracle and reconstructs a
  single erasure bit-exactly,
- the tier planner resolves a single-host correlated loss vs uniform loss
  to the expected tiers,
- E||δ'||² → 0 when every lost block has a surviving fresh replica
  (the fabric extension of Thm 4.2's accounting).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import partition_pytree, tree_sq_norm
from repro.core.checkpoint import init_running_checkpoint
from repro.core.policy import CheckpointPolicy, RecoveryMode, SelectionStrategy
from repro.fabric import (CheckpointFabric, ClusterView, FabricConfig,
                          FailureDomainMap, ParityCodec, RecoveryTier,
                          ReplicaSet)
from repro.fabric.parity import frame_layout, pack_frames
from repro.kernels.parity_xor.kernel import parity_xor_pallas
from repro.kernels.parity_xor.ops import parity_encode, parity_reconstruct
from repro.kernels.parity_xor.ref import parity_xor_ref
from repro.sharding.partition import block_device_homes

RNG = np.random.default_rng(11)


def _params(rows=256, width=6, extra=True):
    p = {"w": jnp.asarray(RNG.normal(size=(rows, width)), jnp.float32)}
    if extra:
        p["b"] = jnp.asarray(RNG.normal(size=(8,)), jnp.float32)
    return p


def _fabric(part, **kw):
    cfg = FabricConfig(n_devices=8, devices_per_host=2, hosts_per_rack=2,
                       use_pallas=False, **kw)
    return CheckpointFabric(part, cfg)


# ---------------------------------------------------------------------------
# domains
# ---------------------------------------------------------------------------

def test_domain_map_topology():
    dm = FailureDomainMap(n_devices=16, devices_per_host=4, hosts_per_rack=2)
    assert dm.n_hosts == 4 and dm.n_racks == 2
    assert int(dm.host_of(5)) == 1 and int(dm.rack_of(13)) == 1
    np.testing.assert_array_equal(dm.devices_in("host", 1), [4, 5, 6, 7])
    failed = dm.sample_domain_failure(np.random.default_rng(0), "rack")
    assert len(failed) == 8 and len(set(dm.rack_of(failed).tolist())) == 1


def test_mtbf_trace_sorted_and_bounded():
    dm = FailureDomainMap(n_devices=8, devices_per_host=2)
    trace = dm.sample_failure_trace(np.random.default_rng(0), 500,
                                    {"device": 80.0, "host": 200.0})
    assert trace, "expected some events over 500 steps"
    steps = [e.step for e in trace]
    assert steps == sorted(steps)
    assert all(0 <= e.step <= 500 for e in trace)
    assert all(e.index < dm.n_domains(e.kind) for e in trace)


# ---------------------------------------------------------------------------
# replica anti-affinity
# ---------------------------------------------------------------------------

def test_replica_placement_anti_affine():
    part = partition_pytree(_params(), 16)
    dm = FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)
    homes = block_device_homes(part, 8)
    rs = ReplicaSet(part, ClusterView(dm, homes))
    # with 2 racks the replica must live in a different rack (hence host)
    assert np.all(np.asarray(dm.rack_of(rs.replica_homes))
                  != np.asarray(dm.rack_of(homes)))
    assert np.all(np.asarray(dm.host_of(rs.replica_homes))
                  != np.asarray(dm.host_of(homes)))


def test_parity_groups_host_disjoint():
    part = partition_pytree(_params(), 16)
    dm = FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)
    homes = block_device_homes(part, 8)
    codec = ParityCodec(part, ClusterView(dm, homes), group_size=3,
                        use_pallas=False)
    hosts = np.asarray(dm.host_of(homes))
    for j, row in enumerate(codec.members):
        ids = row[row >= 0]
        member_hosts = hosts[ids]
        assert len(set(member_hosts.tolist())) == len(ids), \
            f"group {j} has two members on one host"
        # parity block homed on a host with no member
        assert int(dm.host_of(codec.parity_homes[j])) not in set(
            member_hosts.tolist())


# ---------------------------------------------------------------------------
# parity_xor kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(3, 2, 64), (8, 4, 512), (13, 5, 300)])
def test_parity_xor_kernel_matches_ref(shape):
    n, g, e = shape
    frames = jnp.asarray(RNG.integers(-2**31, 2**31, size=shape), jnp.int32)
    base = jnp.asarray(RNG.integers(-2**31, 2**31, size=(n, e)), jnp.int32)
    keep = jnp.asarray(RNG.random((n, g)) < 0.6, jnp.int32)
    got = parity_xor_pallas(frames, base, keep, interpret=True)
    want = parity_xor_ref(frames, base, keep)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_parity_single_erasure_roundtrip_bit_exact():
    n, g, e = 6, 4, 128
    frames = jnp.asarray(RNG.integers(-2**31, 2**31, size=(n, g, e)),
                         jnp.int32)
    valid = jnp.ones((n, g), jnp.int32)
    parity = parity_encode(frames, valid, interpret=True)
    for lost_slot in range(g):
        survivors = valid.at[:, lost_slot].set(0)
        rec = parity_reconstruct(frames, parity, survivors, interpret=True)
        np.testing.assert_array_equal(np.asarray(rec),
                                      np.asarray(frames[:, lost_slot, :]))


def test_pack_frames_roundtrip_through_codec():
    """Codec-level: lose one whole host, reconstruct, values bit-exact."""
    params = _params()
    part = partition_pytree(params, 16)
    dm = FailureDomainMap(n_devices=8, devices_per_host=2, hosts_per_rack=2)
    homes = block_device_homes(part, 8)
    codec = ParityCodec(part, ClusterView(dm, homes), group_size=3,
                        use_pallas=False)
    codec.encode(7, params)
    failed = dm.devices_in("host", 1)
    lost = np.isin(homes, failed)
    available = ~lost
    rec_mask = codec.reconstructable(lost, available, failed, step=7)
    np.testing.assert_array_equal(rec_mask, lost)  # all singly-erased
    frames = codec.reconstruct(params, rec_mask, available)
    want = pack_frames(params, part, codec.layout)
    got = np.asarray(frames)[lost]
    np.testing.assert_array_equal(got, np.asarray(want)[lost])


# ---------------------------------------------------------------------------
# tier planner
# ---------------------------------------------------------------------------

def test_plan_single_host_loss_resolves_to_replicas():
    part = partition_pytree(_params(), 16)
    fab = _fabric(part)
    params = _params()
    fab.maintain(3, params)
    lost, failed = fab.sample_domain_failure(np.random.default_rng(1), "host")
    plan = fab.planner.plan(lost, failed, step=3)
    assert plan.counts["PEER_REPLICA"] == int(lost.sum()) > 0
    assert plan.counts["SURVIVOR"] == int((~lost).sum())


def test_plan_uniform_loss_all_tiers_survive():
    part = partition_pytree(_params(), 16)
    fab = _fabric(part)
    params = _params()
    fab.maintain(3, params)
    lost = np.zeros((part.total_blocks,), bool)
    lost[RNG.choice(part.total_blocks, 5, replace=False)] = True
    plan = fab.planner.plan(lost, np.empty((0,), np.int32), step=3)
    # no device died → every replica survives
    assert plan.counts["PEER_REPLICA"] == 5
    assert plan.counts["RUNNING_CKPT"] == plan.counts["DISK"] == 0


def test_plan_cascades_replica_parity_ckpt_disk():
    part = partition_pytree(_params(), 16)
    fab = _fabric(part, replicate=False)   # parity-only fabric
    params = _params()
    fab.maintain(3, params)
    lost, failed = fab.sample_domain_failure(np.random.default_rng(1), "host")
    plan = fab.planner.plan(lost, failed, step=3)
    assert plan.counts["PARITY"] == int(lost.sum()) > 0
    # stale parity (param update since encode) is unusable → running ckpt
    plan_stale = fab.planner.plan(lost, failed, step=4)
    assert plan_stale.counts["PARITY"] == 0
    assert plan_stale.counts["RUNNING_CKPT"] == int(lost.sum())
    # kill the ckpt homes too → disk
    bare = _fabric(part, replicate=False, parity=False)
    ckpt_failed = np.unique(np.concatenate(
        [failed, bare.planner.ckpt_homes[lost]]))
    plan_disk = bare.planner.plan(lost, ckpt_failed, step=3)
    assert plan_disk.counts["DISK"] == int(lost.sum())


# ---------------------------------------------------------------------------
# perturbation accounting end-to-end (Thm 4.1/4.2 extension)
# ---------------------------------------------------------------------------

def test_replica_recovery_zero_perturbation():
    """E||δ'||² ≈ 0 when every lost block has a surviving fresh replica,
    while checkpoint-only recovery applies a strictly positive δ'."""
    params = _params()
    part = partition_pytree(params, 16)
    ckpt = init_running_checkpoint(params, part)
    live = jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape), jnp.float32),
        params)
    fab = _fabric(part)
    fab.maintain(9, live)
    sqs, ckpt_sqs = [], []
    for seed in range(10):
        lost, failed = fab.sample_domain_failure(
            np.random.default_rng(seed), "host")
        rec, info = fab.on_failure(live, ckpt.values, lost, failed, step=9)
        sqs.append(float(tree_sq_norm(rec, live)))
        bare = _fabric(part, replicate=False, parity=False)
        bare.maintain(9, live)
        rec_b, _ = bare.on_failure(live, ckpt.values, lost, failed, step=9)
        ckpt_sqs.append(float(tree_sq_norm(rec_b, live)))
    assert np.mean(sqs) < 1e-12
    assert np.mean(ckpt_sqs) > 1e-3    # strict: checkpoint recovery perturbs
    assert np.mean(sqs) < np.mean(ckpt_sqs)


def test_controller_routes_through_fabric():
    params = _params()
    pol = CheckpointPolicy(fraction=1.0, full_interval=4,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL, block_rows=16)
    from repro.core.controller import FTController
    ctl = FTController(params, pol,
                       fabric=FabricConfig(n_devices=8, devices_per_host=2,
                                           use_pallas=False))
    live = jax.tree_util.tree_map(lambda x: x + 1.0, params)
    ctl.maintain(4, live)              # fabric fresh; running ckpt still x⁰
    lost, failed = ctl.sample_domain_failure("host")
    rec, info = ctl.on_failure(live, lost, failed_devices=failed, step=4)
    assert info["applied_sq"] == pytest.approx(0.0, abs=1e-12)
    assert info["tier_counts"]["PEER_REPLICA"] == int(lost.sum())
    assert info["partial_sq"] > 0      # what checkpoint-only would have paid
    # uniform loss (no dead devices): replicas also cover everything
    lost_u = np.asarray(ctl.sample_failure(0.5))
    rec2, info2 = ctl.on_failure(live, lost_u, step=4)
    assert info2["applied_sq"] == pytest.approx(0.0, abs=1e-12)


def test_train_loop_correlated_injection():
    """SPMD trainer path: fail_domain="host" routes through the fabric."""
    from repro.configs import get_config
    from repro.data.pipeline import ShardedLMDataset
    from repro.sharding import single_device_ctx
    from repro.training import TrainLoop, TrainLoopConfig
    ctx = single_device_ctx()
    cfg = get_config("qwen2-1.5b", reduced=True)
    pol = CheckpointPolicy.scar(fraction=0.25, interval=4)
    loop_cfg = TrainLoopConfig(
        policy=pol, fail_domain="host",
        fabric=FabricConfig(n_devices=8, devices_per_host=2,
                            use_pallas=False))
    loop = TrainLoop(cfg, ctx, loop_cfg=loop_cfg)
    state = loop.init_state()
    ds = ShardedLMDataset(cfg, batch=2, seq=64, ctx=ctx)
    state = loop.run(state, iter(ds), 4)
    state, info = loop.inject_failure(state)
    assert "tier_counts" in info
    lost = sum(v for k, v in info["tier_counts"].items() if k != "SURVIVOR")
    assert lost > 0
    # fresh fabric (maintain runs every step) → live-value recovery
    assert info["applied_sq"] == pytest.approx(0.0, abs=1e-9)
    state = loop.run(state, iter(ds), 2)
    assert all(np.isfinite(m["loss"]) for m in loop.metrics)


def test_train_loop_config_validates_fail_domain():
    with pytest.raises(ValueError):
        from repro.training import TrainLoopConfig
        TrainLoopConfig(fail_domain="host")   # fabric missing


def test_classic_run_with_failure_fabric_lowers_perturbation():
    from repro.models.classic import make_model
    from repro.training import run_clean, run_with_failure
    model = make_model("mlr", n=600, dim=64, n_classes=5, batch=200)
    clean = run_clean(model, 90)["losses"]
    pol = CheckpointPolicy(fraction=0.25, full_interval=8,
                           strategy=SelectionStrategy.ROUND_ROBIN,
                           recovery=RecoveryMode.PARTIAL,
                           block_rows=model.block_rows)
    kw = dict(fail_iter=13, fail_fraction=0.5, max_iters=90, seed=0,
              clean_losses=clean, fail_domain="host")
    tiered = run_with_failure(model, pol, fabric=FabricConfig(
        n_devices=8, devices_per_host=2, use_pallas=False), **kw)
    bare = run_with_failure(model, pol, fabric=FabricConfig(
        n_devices=8, devices_per_host=2, replicate=False, parity=False,
        use_pallas=False), **kw)
    assert tiered["recovery"]["applied_sq"] <= 1e-12
    assert bare["recovery"]["applied_sq"] > tiered["recovery"]["applied_sq"]
    assert tiered["iteration_cost"] <= bare["iteration_cost"]
