"""SPMD LM trainer with SCAR fault tolerance as a first-class feature.

``TrainLoop`` owns:

- the jitted ``train_step`` (value_and_grad + optimizer update), with
  params/opt-state sharded per :mod:`repro.sharding.partition` when a mesh
  is present;
- an :class:`repro.core.controller.FTController` over the *parameter*
  PyTree (optimizer moments are recoverable state too — SCAR checkpoints
  params; Adam moments after a partial restore are simply kept, which is
  itself a perturbation the theory covers; see DESIGN.md);
- **arena-resident training state** (the default when the controller's
  fabric is arena-capable and no mesh is configured): the live params are
  the flat parameter arena (:class:`~repro.training.train_state.ArenaTrainState`),
  donated through the jitted step, and the per-step controller calls
  (``maintain``/``maybe_checkpoint``) consume ``state.arena`` directly —
  the maintenance sweep runs pack-free (pure 2-read/1-write) and the
  partial save sources straight from the training state. The PyTree path
  stays available via ``TrainLoopConfig(arena_state=False)`` for
  non-arena-compatible models;
- optional fault injection (iteration sampled from a geometric
  distribution, as in the paper's §5.3), either the paper's uniform
  block-loss model or correlated whole-domain loss
  (``fail_domain="host"``) routed through the checkpoint fabric's tier
  planner (:mod:`repro.fabric`);
- trace-driven soak mode (``mtbf=``): an MTBF-sampled multi-event failure
  schedule where failed domains stay dead in the fabric's cluster view
  (elastic fabrics re-home/re-seed across the survivors) and optionally
  heal ``heal_after`` steps later — long-horizon degraded-mode training
  with per-event tier/perturbation accounting in ``metrics`` and
  ``controller.stats["events"]``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import FTController
from repro.core.policy import CheckpointPolicy
from repro.models import get_model
from repro.optim.optimizers import Optimizer, adamw
from repro.sharding.partition import DistContext, named_shardings
from repro.telemetry.recorder import NULL_RECORDER, Histogram
from repro.training.train_state import ArenaTrainState, TrainState

PyTree = Any


@dataclasses.dataclass
class TrainLoopConfig:
    policy: Optional[CheckpointPolicy] = None
    fail_prob: float = 0.0          # per-iteration geometric failure prob
    fail_fraction: float = 0.5      # fraction of blocks lost per failure
    fail_domain: str = "uniform"    # "uniform" | "device" | "host" | "rack"
    fabric: Optional[Any] = None    # FabricConfig → tiered recovery fabric
    # arena-resident training state: the live params ARE the flat arena
    # (needs an arena-capable fabric; works single-device and on SPMD
    # meshes — the arena then carries the flat per-device sharding and
    # the sweep runs shard-local). When requested but the fabric cannot
    # engage it (non-arena dtypes, custom scorers, partial tiers) the
    # loop warns and records a ``fabric/arena_gated`` event before
    # falling back to the PyTree path — set False to silence that and
    # force the tree path deliberately.
    arena_state: bool = True
    # elastic SPMD mesh: with a meshed elastic fabric, a domain loss
    # shrinks the mesh to the survivors (arena relayouted, step re-jitted,
    # training continues) and a heal re-grows it. None = auto (on exactly
    # when the arena path engaged on a mesh and the fabric is elastic).
    elastic_mesh: Optional[bool] = None
    # record per-step maintenance overhead (``overhead_seconds`` in
    # metrics): blocks on the sweep's device outputs each step so the
    # number is the maintenance work, not its dispatch. Disable on
    # accelerators when the sweep should overlap the next step's
    # dispatch instead of being measured.
    measure_overhead: bool = True
    # trace-driven soak mode: per-domain-kind MTBF means (in steps) sampled
    # into a multi-event failure schedule each run(); failed domains stay
    # dead in the cluster view, and optionally heal ``heal_after`` steps
    # later (re-admitting their devices to the placement engine)
    mtbf: Optional[dict] = None     # e.g. {"host": 200.0, "device": 80.0}
    # deterministic event schedule: (step, kind, index) triples (or
    # FailureEvent objects) applied exactly, alongside any mtbf-sampled
    # trace — reproducible soaks and elastic-mesh tests
    fail_schedule: Optional[list] = None
    heal_after: Optional[int] = None
    # silent-error soak: in-arena bit flips injected at these steps — an
    # int step (random block/word/bit) or a (step, block) pair targeting
    # one block. The flip corrupts the replica snapshot invisibly; an RS
    # fabric's scrub detects/corrects it, while an XOR fabric carries the
    # corruption into its next replica-tier recovery where the measured
    # ‖δ′‖² prices the undetected window honestly.
    flip_schedule: Optional[list] = None
    # integrity-scrub cadence in steps (0 = never). Runs the fabric's
    # syndrome pass after maintenance; detections land in metrics and the
    # perturbation ledger at ‖δ′‖² ≈ 0 (corrected in place).
    scrub_interval: int = 0
    # telemetry sink (repro.telemetry.Recorder): events/spans/ledger for
    # the whole loop + its controller/fabric/store. Default NULL_RECORDER —
    # every emit point is a no-op and the hot path is unchanged.
    recorder: Optional[Any] = None
    log_every: int = 10
    seed: int = 0

    def __post_init__(self):
        if self.fail_domain != "uniform" and self.fabric is None:
            raise ValueError("correlated fail_domain injection needs a "
                             "fabric (set TrainLoopConfig.fabric)")
        if (self.mtbf is not None or self.fail_schedule) \
                and self.fabric is None:
            raise ValueError("trace-driven soak mode needs a fabric "
                             "(set TrainLoopConfig.fabric)")
        if (self.flip_schedule or self.scrub_interval) \
                and self.fabric is None:
            raise ValueError("bit-flip injection / integrity scrubs need "
                             "a fabric (set TrainLoopConfig.fabric)")


class TrainLoop:
    def __init__(self, cfg: ModelConfig, ctx: DistContext,
                 optimizer: Optional[Optimizer] = None,
                 loop_cfg: Optional[TrainLoopConfig] = None,
                 store=None):
        self.cfg = cfg
        self.ctx = ctx
        self.ops = get_model(cfg)
        self.optimizer = optimizer or adamw(3e-4)
        self.loop_cfg = loop_cfg or TrainLoopConfig()
        self._store = store
        self._rng = np.random.default_rng(self.loop_cfg.seed)
        self.controller: Optional[FTController] = None
        self.metrics: list[dict] = []
        self._redundancy_flags: list[bool] = []
        self.arena_layout = None          # set when the arena path engages
        # elastic-mesh bookkeeping: the base (full) mesh, the mesh the
        # step currently runs on, which fabric logical device sits at
        # each current mesh position, and whether a resize has happened
        # (batches are re-placed onto the current mesh only after one —
        # the never-resized path is byte-for-byte the old loop)
        self._base_mesh = ctx.mesh
        self._cur_mesh = ctx.mesh
        self._mesh_logical = (np.arange(ctx.mesh.devices.size, dtype=np.int32)
                              if ctx.mesh is not None else None)
        self._mesh_resized = False
        self.recorder = (self.loop_cfg.recorder
                         if self.loop_cfg.recorder is not None
                         else NULL_RECORDER)
        # clean-step maintenance-overhead distribution: feeds the
        # p50/p95/max in overhead_summary(). A real recorder shares its
        # named histogram; otherwise a private one (same type, no sink)
        self._overhead_hist = (
            self.recorder.histogram("train/overhead_seconds")
            if self.recorder.enabled else Histogram())
        # per-phase split of the clean-step overhead (sweep dispatch,
        # checkpoint save, fence wait) — overhead_summary() attributes
        # the async overlap win to the phase that shrank. The loop-side
        # fence histogram holds sync-mode blocking samples; async-mode
        # deferred-fence waits live in the fabric's own fence histogram
        # and the two are merged at summary time.
        self._sweep_hist = (self.recorder.histogram("train/sweep_seconds")
                            if self.recorder.enabled else Histogram())
        self._save_hist = (self.recorder.histogram("train/save_seconds")
                           if self.recorder.enabled else Histogram())
        self._fence_hist = (self.recorder.histogram("train/fence_seconds")
                            if self.recorder.enabled else Histogram())

        from repro.training.step import make_train_step
        self._train_step = jax.jit(
            make_train_step(self.ops, cfg, ctx, self.optimizer),
            donate_argnums=(0,))
        self._arena_step = None           # built lazily by init_state

    # -- initialization ------------------------------------------------------

    def init_state(self, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.loop_cfg.seed)
        if self.ctx.mesh is not None:
            p_shape = jax.eval_shape(
                lambda r: self.ops.init_params(r, self.cfg), rng)
            shardings = named_shardings(p_shape, self.ctx)
            params = jax.jit(self.ops.init_params, static_argnums=(1,),
                             out_shardings=shardings)(rng, self.cfg)
        else:
            params = self.ops.init_params(rng, self.cfg)
        if self.loop_cfg.policy is not None:
            self.controller = FTController(params, self.loop_cfg.policy,
                                           store=self._store,
                                           fabric=self.loop_cfg.fabric,
                                           recorder=self.loop_cfg.recorder,
                                           mesh=self.ctx.mesh)
        if (self.loop_cfg.arena_state and self.controller is not None
                and self.controller.arena_ready):
            # arena-resident training state: pack once here, never again —
            # every subsequent step donates the arena through the jitted
            # update and the controller reads it in place. On a mesh the
            # pack lands the flat per-device sharding and the moments are
            # placed to match, so the whole state is SPMD from step one.
            self.arena_layout = self.controller.arena_layout
            if self._arena_step is None:
                from repro.training.step import make_arena_train_step
                self._arena_step = jax.jit(
                    make_arena_train_step(self.ops, self.cfg, self.ctx,
                                          self.optimizer,
                                          self.arena_layout),
                    donate_argnums=(0,))
            arena = self.controller.pack_live(params)
            state = ArenaTrainState.create(arena, self.optimizer,
                                           self.arena_layout)
            if self.ctx.mesh is not None:
                from repro.sharding.partition import shard_arena_state
                state = shard_arena_state(state, self.ctx.mesh)
            return state
        if self.loop_cfg.arena_state and self.controller is not None \
                and self.loop_cfg.fabric is not None:
            # arena-resident state was requested (the default) with a
            # fabric, but the fabric could not build an arena layout.
            # Since the word-level arena, quantized dtypes (bf16/f16/fp8/
            # int8…) are arena-native; only truly word-unpackable leaves
            # (f64, int64, complex, bool), custom scorers, partial tiers,
            # or mixed-dtype models on an SPMD mesh gate here. Never fall
            # back silently: the tree path packs every maintained step, a
            # real perf cliff on SPMD meshes.
            import warnings
            msg = ("arena_state=True but the fabric is not arena-capable "
                   "(word-unpackable dtype such as f64/int64/bool, custom "
                   "scorer, partial tiers, or mixed dtypes on a mesh); "
                   "falling back to PyTree training state (per-step packs). "
                   "Set TrainLoopConfig(arena_state=False) to silence.")
            warnings.warn(msg, stacklevel=2)
            if self.recorder.enabled:
                self.recorder.event("fabric/arena_gated", reason=msg)
        return TrainState.create(params, self.optimizer)

    # -- live-state plumbing (both representations) --------------------------

    @staticmethod
    def _live(state):
        """The live parameter value in its canonical form: the flat arena
        for ArenaTrainState, the tree for TrainState. Controller entry
        points accept either."""
        return state.arena if isinstance(state, ArenaTrainState) \
            else state.params

    @staticmethod
    def _with_live(state, new_live):
        if isinstance(state, ArenaTrainState):
            return ArenaTrainState(new_live, state.opt_state, state.step,
                                   state.layout)
        return TrainState(new_live, state.opt_state, state.step)

    # -- elastic SPMD mesh ---------------------------------------------------

    def _elastic_enabled(self, state) -> bool:
        """Whether this run() may shrink/re-grow the mesh on domain
        events: arena-resident state on a mesh with an elastic meshed
        fabric. ``elastic_mesh=True`` with missing prerequisites is a
        config error, not a silent no-op."""
        want = self.loop_cfg.elastic_mesh
        if want is False:
            return False
        fab = self.controller.fabric if self.controller is not None else None
        ok = (isinstance(state, ArenaTrainState)
              and self._base_mesh is not None
              and fab is not None and fab.cfg.elastic
              and getattr(fab, "mesh", None) is not None)
        if want and not ok:
            raise ValueError(
                "elastic_mesh=True needs arena-resident state on a mesh "
                "with an elastic meshed fabric (FabricConfig(elastic=True) "
                "and a DistContext mesh whose size matches n_devices)")
        return ok

    def _place_batch(self, batch):
        """Re-place a batch onto the current (possibly shrunk) mesh:
        batch dim over the data axis. Only runs after a resize — the
        dataset's own placement targets the base mesh, and arrays
        committed there cannot mix with survivor-mesh state in one jit."""
        from jax.sharding import NamedSharding, PartitionSpec
        mesh = self._cur_mesh
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(np.asarray(x), sh), batch)

    def _maybe_resize(self, state, step: int, rec: dict):
        """Shrink or re-grow the mesh to the fabric's alive-device set.

        The survivor count is the largest k ≤ alive that divides the
        global batch (the data axis must tile it); survivors keep their
        fabric logical ids, so failure domains stay meaningful on the
        shrunk topology. The arena and the 1-D adam moments relayout
        bit-exactly (the data region is shard-count-invariant; only the
        zero pad tail is resized), the step re-jits against the survivor
        mesh, and the fabric re-homes/re-seeds/re-stripes before an
        immediate forced maintain so every tier is fresh on the new
        placement."""
        fab = self.controller.fabric
        alive = fab.view.alive_devices()
        k = int(alive.size)
        bdim = self._last_batch_dim or k
        while k > 1 and bdim % k != 0:
            k -= 1
        survivors = alive[:k]
        if np.array_equal(survivors, self._mesh_logical):
            return state
        from repro.launch.mesh import mesh_devices, survivor_mesh
        base_devs = mesh_devices(self._base_mesh)
        if k == len(base_devs):
            new_mesh = self._base_mesh    # full re-grow: original shape
        else:
            new_mesh = survivor_mesh([base_devs[int(i)] for i in survivors])
        old_layout = self.arena_layout
        new_layout = fab.resize_mesh(new_mesh, survivors, step=step)
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.arena import relayout_arena
        from repro.sharding.partition import arena_sharding
        ash = arena_sharding(new_mesh)
        rep_sh = NamedSharding(new_mesh, PartitionSpec())

        def move(x):
            if getattr(x, "ndim", None) == 1 \
                    and x.size == old_layout.total_words:
                return relayout_arena(x, old_layout, new_layout,
                                      out_sharding=ash)
            if getattr(x, "ndim", None) == 1 \
                    and x.size == old_layout.total_values:
                # value-domain moment mirrors of a quantized layout
                # (total_values > total_words); same shard-count-invariant
                # data region argument, value-granular
                from repro.core.arena import relayout_values
                return relayout_values(x, old_layout, new_layout,
                                       out_sharding=ash)
            # scalars (adam step count) re-commit replicated on the new
            # mesh — a leaf left on the old device set cannot enter the
            # re-jitted step
            return jax.device_put(x, rep_sh)

        state = ArenaTrainState(move(state.arena),
                                jax.tree_util.tree_map(move, state.opt_state),
                                move(state.step), new_layout)
        from repro.training.step import make_arena_train_step
        ctx = dataclasses.replace(self.ctx, mesh=new_mesh)
        self._arena_step = jax.jit(
            make_arena_train_step(self.ops, self.cfg, ctx, self.optimizer,
                                  new_layout),
            donate_argnums=(0,))
        self.arena_layout = new_layout
        self.controller.rebind_arena()
        # tiers were invalidated by the re-home/re-stripe: refresh them
        # from the relayouted live arena on the new placement
        fab.maintain(step, state.arena, force=True)
        self._cur_mesh = new_mesh
        self._mesh_logical = survivors
        self._mesh_resized = True
        rec["mesh_resize"] = {"shards": int(new_layout.shards),
                              "alive_devices": int(alive.size)}
        return state

    # -- run loop -------------------------------------------------------------

    def run(self, state, batches, n_steps: int,
            on_step: Optional[Callable[[int, float], None]] = None):
        it = iter(batches)
        events_at = self._sample_trace(n_steps)
        heal_at: dict[int, list] = {}
        flips_at: dict[int, list] = {}
        for fl in (self.loop_cfg.flip_schedule or []):
            s, blk = (int(fl[0]), int(fl[1])) \
                if isinstance(fl, (tuple, list)) else (int(fl), None)
            flips_at.setdefault(max(1, min(s, n_steps)), []).append(blk)
        elastic = self._elastic_enabled(state)
        self._last_batch_dim = None
        for i in range(1, n_steps + 1):
            # re-read each iteration: an elastic resize swaps the jitted
            # step under our feet mid-run
            step_fn = (self._arena_step if isinstance(state, ArenaTrainState)
                       else self._train_step)
            batch = next(it)
            if elastic:
                self._last_batch_dim = int(
                    jax.tree_util.tree_leaves(batch)[0].shape[0])
                if self._mesh_resized:
                    batch = self._place_batch(batch)
            t0 = time.perf_counter()
            with self.recorder.span("train_step", step=i):
                state, loss = step_fn(state, batch)
                loss = float(loss)   # fences on the loss output
            dt = time.perf_counter() - t0
            rec = {"step": int(state.step), "loss": loss, "seconds": dt}

            if self.controller is not None:
                # maintain first: the fused maintenance sweep scores the
                # blocks against the running checkpoint in the same read,
                # and a same-step partial save below reuses those scores
                tm0 = time.perf_counter()
                live = self._live(state)
                self.controller.maintain(int(state.step), live)
                t_maint = time.perf_counter()
                with self.recorder.span("save", step=int(state.step)):
                    if self.controller.maybe_checkpoint(int(state.step),
                                                        live):
                        rec["checkpointed"] = True
                t_save = time.perf_counter()
                fab = self.controller.fabric
                async_mode = (fab is not None
                              and getattr(fab.cfg, "async_maintain", False))
                # per-step fault-tolerance overhead (maintain + save),
                # excluding the rare failure/heal events timed below —
                # the examples report this next to the step time. Sync
                # mode blocks on the sweep's device outputs first:
                # checkpoint_now only blocks on save steps, and under
                # async dispatch a maintain-only step would otherwise
                # book dispatch time here and push the sweep's compute
                # into the NEXT step's "seconds". Async-maintain mode
                # must NOT block — hiding the sweep under the next step
                # is the whole point; its overhead is the dispatch cost,
                # and the sweep's un-hidden remainder books into the
                # fabric's fence histogram at the deferred fence instead.
                t_fence = t_save
                if self.loop_cfg.measure_overhead:
                    if fab is not None and not async_mode:
                        fab.block_until_maintained()
                        t_fence = time.perf_counter()
                    rec["overhead_seconds"] = t_fence - tm0
                evs = events_at.pop(i, [])
                if len(evs) > 1:
                    # simultaneous multi-domain loss: every event resolves
                    # against the pre-failure view and the union recovers
                    # in ONE tier-planned pass (the RS tier's multi-erasure
                    # case — applying them sequentially would let the first
                    # recovery's re-encode hide the correlation)
                    names = ",".join(f"{e.kind}:{e.index}" for e in evs)
                    with self.recorder.span("recovery", step=int(state.step),
                                            domain=names):
                        live, info = self.controller.on_domain_events(
                            live, [(e.kind, e.index) for e in evs],
                            step=int(state.step))
                    state = self._with_live(state, live)
                    rec.setdefault("failures", []).append(info)
                    if self.loop_cfg.heal_after is not None:
                        applied = {(a["kind"], a["index"])
                                   for a in info.get("events", [])}
                        for ev in evs:
                            if (ev.kind, ev.index) in applied:
                                heal_at.setdefault(
                                    i + self.loop_cfg.heal_after,
                                    []).append(ev)
                elif evs:
                    ev = evs[0]
                    with self.recorder.span("recovery", step=int(state.step),
                                            domain=f"{ev.kind}:{ev.index}"):
                        live, info = self.controller.on_domain_event(
                            live, ev.kind, ev.index, step=int(state.step))
                    state = self._with_live(state, live)
                    rec.setdefault("failures", []).append(info)
                    if (self.loop_cfg.heal_after is not None
                            and not info.get("skipped")):
                        heal_at.setdefault(i + self.loop_cfg.heal_after,
                                           []).append(ev)
                for ev in heal_at.pop(i, []):
                    with self.recorder.span("heal", step=int(state.step),
                                            domain=f"{ev.kind}:{ev.index}"):
                        heal = self.controller.heal_domain(
                            ev.kind, ev.index, live, step=int(state.step))
                    rec.setdefault("heals", []).append(heal)
                if elastic and ("failures" in rec or "heals" in rec):
                    # domain events changed the survivor set: shrink the
                    # mesh to the alive devices (or re-grow after a heal),
                    # relayout the arena state, and re-jit the step —
                    # training continues on the new topology next step
                    state = self._maybe_resize(state, int(state.step), rec)
                for blk in flips_at.pop(i, []):
                    # soft-error injection: corrupt the replica snapshot
                    # invisibly — only the scrub (or the honestly-priced
                    # perturbation of a later replica recovery) sees it
                    if fab is not None and fab.replicas is not None \
                            and fab.replicas.arena is not None:
                        where = fab.inject_arena_bit_flip(block=blk,
                                                          rng=self._rng)
                        rec.setdefault("bit_flips", []).append(where)
                if (self.loop_cfg.scrub_interval
                        and i % self.loop_cfg.scrub_interval == 0):
                    with self.recorder.span("scrub", step=int(state.step)):
                        sc = self.controller.scrub(step=int(state.step))
                    if sc["checked"]:
                        rec["scrub"] = {"detected": sc["detected"],
                                        "corrected": sc["corrected"]}
                if (self.loop_cfg.fail_prob > 0
                        and self._rng.random() < self.loop_cfg.fail_prob):
                    with self.recorder.span("recovery",
                                            step=int(state.step)):
                        new_live, info = self._inject(state)
                    state = self._with_live(state, new_live)
                    rec["failure"] = info
                # clean-step overhead sample: failure/heal steps are
                # excluded so the distribution answers "what does fault
                # tolerance cost when nothing is on fire"
                if "overhead_seconds" in rec and "failures" not in rec \
                        and "heals" not in rec and "failure" not in rec:
                    self._overhead_hist.observe(rec["overhead_seconds"])
                    self._sweep_hist.observe(t_maint - tm0)
                    self._save_hist.observe(t_save - t_maint)
                    if not async_mode:
                        self._fence_hist.observe(t_fence - t_save)
                if self.controller.fabric is not None:
                    # per-step placement health — availability_summary()
                    # folds these into the soak goodput report
                    full = self.controller.fabric.redundancy_state()["full"]
                    rec["redundancy_full"] = full
                    self._redundancy_flags.append(full)
            self.metrics.append(rec)
            if on_step is not None:
                on_step(i, loss)
        # epoch boundary: settle any in-flight async sweep (the deferred
        # fence's last consume point) and drain the background store
        # writer so run() returns with redundancy published and durable —
        # in async mode this is where store flushes live now, not on the
        # per-step hot path
        if self.controller is not None:
            if self.controller.fabric is not None:
                self.controller.fabric.block_until_maintained()
            if self.controller.store is not None \
                    and hasattr(self.controller.store, "flush"):
                self.controller.store.flush()
        return state

    def availability_summary(self) -> dict:
        """Aggregate this loop's soak accounting (per-event tier counts +
        per-step redundancy flags) into the availability/goodput report —
        see :func:`repro.fabric.availability.summarize_availability`."""
        from repro.fabric.availability import summarize_availability
        events = (self.controller.stats["events"]
                  if self.controller is not None else [])
        out = summarize_availability(events, self._redundancy_flags)
        if self.recorder.enabled:
            led = self.recorder.ledger.summary()
            out["telemetry"] = {
                "events_total": len(self.recorder.events),
                "recoveries_priced": led["n_events"],
                "iterations_owed_total": led["iterations_owed_total"]}
        return out

    def overhead_summary(self) -> dict:
        """Per-step wall-clock split (train step vs fault-tolerance
        maintain+save) plus the fabric's accounted maintenance bytes —
        what the arena-resident refactor is buying per step. The
        ``overhead_seconds_*`` distribution covers **clean steps only**
        (failure/heal-event steps excluded at observe time) and comes
        from the telemetry histogram, so the p95 a dashboards reads and
        the one reported here are the same samples.

        ``phases`` attributes the overhead: ``sweep`` (maintain call),
        ``save`` (maybe_checkpoint), ``fence`` (blocking waits — the
        loop's sync-mode blocks merged with the fabric's deferred
        async-fence waits). ``overlap_efficiency`` is the fraction of
        async sweep wall-clock hidden under the trainer's compute
        (0.0 in sync mode — nothing is overlapped)."""
        steps = [m["seconds"] for m in self.metrics]
        over = self._overhead_hist.summary()
        out = {"steps": len(steps),
               "step_seconds_mean": float(np.mean(steps)) if steps else 0.0,
               "overhead_seconds_mean": over["mean"],
               "overhead_seconds_p50": over["p50"],
               "overhead_seconds_p95": over["p95"],
               "overhead_seconds_max": over["max"],
               "overhead_clean_steps": over["count"],
               "arena_state": self.arena_layout is not None}
        fab = (self.controller.fabric
               if self.controller is not None else None)
        fence = Histogram()
        fence.samples = list(self._fence_hist.samples)
        if fab is not None:
            fence.samples += list(fab.fence_hist.samples)
        out["phases"] = {"sweep": self._sweep_hist.summary(),
                         "save": self._save_hist.summary(),
                         "fence": fence.summary()}
        out["overlap_efficiency"] = (fab.overlap_efficiency()
                                     if fab is not None else 0.0)
        if self.controller is not None and self.controller.fabric is not None:
            fab = self.controller.fabric
            # one parity encode per maintained step (fused or not) under
            # the default same-interval tiers — the per-step denominator
            maintains = max(fab.stats["parity_encodes"], 1)
            out["maintain_bytes_per_step"] = (
                fab.stats["maintain_bytes_moved"] // maintains)
            out["arena_resident_maintains"] = \
                fab.stats["arena_resident_maintains"]
            out["async_maintains"] = fab.stats["async_maintains"]
        return out

    def _sample_trace(self, n_steps: int) -> dict[int, list]:
        """Soak schedule for one run(): loop-iteration → events. The
        mtbf-sampled trace plus any explicit ``fail_schedule`` entries.
        Empty without either (or without a controller to recover)."""
        if self.controller is None or self.controller.fabric is None:
            return {}
        trace = []
        if self.loop_cfg.mtbf is not None:
            trace += self.controller.fabric.domains.sample_failure_trace(
                self._rng, n_steps, self.loop_cfg.mtbf)
        if self.loop_cfg.fail_schedule:
            from repro.fabric.domains import FailureEvent
            trace += [ev if isinstance(ev, FailureEvent)
                      else FailureEvent(int(ev[0]), str(ev[1]), int(ev[2]))
                      for ev in self.loop_cfg.fail_schedule]
        events_at: dict[int, list] = {}
        for ev in sorted(trace, key=lambda e: e.step):
            events_at.setdefault(max(1, min(ev.step, n_steps)),
                                 []).append(ev)
        return events_at

    def _inject(self, state) -> tuple[Any, dict]:
        """One failure event per the configured model (uniform/correlated).
        Returns the recovered live value in the state's own form."""
        live = self._live(state)
        if self.loop_cfg.fail_domain == "uniform":
            lost = self.controller.sample_failure(self.loop_cfg.fail_fraction)
            return self.controller.on_failure(live, lost,
                                              step=int(state.step))
        lost, failed = self.controller.sample_domain_failure(
            self.loop_cfg.fail_domain)
        return self.controller.on_failure(live, lost,
                                          failed_devices=failed,
                                          step=int(state.step))

    def inject_failure(self, state, fraction: Optional[float] = None,
                       ) -> tuple[Any, dict]:
        """Explicit failure injection (for experiments/examples)."""
        assert self.controller is not None, "enable a CheckpointPolicy first"
        if fraction is not None:
            lost = self.controller.sample_failure(fraction)
            new_live, info = self.controller.on_failure(
                self._live(state), lost, step=int(state.step))
        else:
            new_live, info = self._inject(state)
        return self._with_live(state, new_live), info
