"""Pallas TPU kernel: per-block squared-L2 distance (SCAR priority scoring).

This is SCAR's checkpoint hot loop: every ``rC`` iterations the coordinator
scores *all* parameter blocks by ``Σ (θ_i − z_i)²`` against the running
checkpoint. The kernel fuses subtract/square/reduce so each element of θ
and z is read from HBM exactly once and no (θ − z) intermediate is ever
materialized — the operation is purely memory-bound, so one-pass streaming
through VMEM is the roofline-optimal schedule.

Layout: inputs are (n_blocks, E) with E = block_rows·row_width padded to a
multiple of 128 lanes. Grid is (⌈n_blocks/BB⌉, ⌈E/BE⌉); the j axis walks
element tiles and accumulates partial sums into the (BB,)-shaped output
block, which lives in VMEM across the j sweep (revisiting grid pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BB = 8      # blocks per tile (sublane-friendly)
BE = 512    # elements per tile (lanes; multiple of 128)


def _block_dist_kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a - b
    out_ref[...] += jnp.sum(d * d, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_dist_pallas(a: jnp.ndarray, b: jnp.ndarray,
                      interpret: bool = False) -> jnp.ndarray:
    """a, b: (n_blocks, E) → (n_blocks,) f32 squared distances.

    Pads both axes to tile multiples (zero padding contributes 0).
    """
    n, e = a.shape
    n_pad = -n % BB
    e_pad = -e % BE
    if n_pad or e_pad:
        a = jnp.pad(a, ((0, n_pad), (0, e_pad)))
        b = jnp.pad(b, ((0, n_pad), (0, e_pad)))
    np_, ep_ = a.shape
    grid = (np_ // BB, ep_ // BE)
    out = pl.pallas_call(
        _block_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BB, BE), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BE), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BB,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:n]
